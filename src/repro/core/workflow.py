"""Event-driven workflow engine: concurrent function DAGs on virtual time.

A workflow is a DAG of named functions.  Each function is user logic with the
signature ``handler(ctx, payload) -> payload`` where ``ctx`` exposes the XDT
API (paper Table 1): ``ctx.invoke(fn, obj)``, ``ctx.put(obj, n) -> ref``,
``ctx.get(ref) -> obj``.  Placement is delegated to the control plane
(:mod:`repro.core.scheduler`), transfers to a :class:`TransferEngine`.

Execution model
---------------
The engine runs on the discrete-event :class:`~repro.core.cluster.Simulator`:
scheduler, transfer accounting, and per-request latency records all share one
:class:`~repro.core.clock.VirtualClock`.  Many workflow *requests* can be in
flight at once (``submit`` + ``drain``), their invocations overlap in virtual
time, and cold starts gate execution exactly as the autoscaler decides.

Two handler styles:

* **Plain handlers** (``def h(ctx, payload): return ...``) run atomically at
  one virtual instant; the virtual time they owe — cold-start waits, modeled
  transfer seconds from ``ctx.get`` (puts are producer-local buffering and
  charge nothing; the through-storage round-trip is billed at the pull),
  ``ctx.sleep`` compute, the function's registered ``service_time`` —
  accrues as *debt* that the engine pays as one timeout after the handler
  body.  ``ctx.invoke`` is a blocking inline sub-invocation, as before.
* **Generator handlers** (``def h(ctx, payload): ... yield ...``) interleave
  with the rest of the cluster at every yield.  Yield a number to spend
  compute seconds, an :class:`AsyncResult` from ``ctx.call(fn, obj)`` to
  await one concurrent sub-invocation, or a list of them for fan-out/fan-in
  that actually overlaps.

Semantics (paper §4.2.2), unchanged from the synchronous engine:

* **At-most-once per invocation id** — invocation ids are issued from a
  monotonic high-watermark counter, so an id at or below the watermark can
  never be executed (re-issued) again; :class:`InvocationReplayed` guards the
  invariant without keeping every id ever issued alive in a set.
* **Producer-death recovery** — if a consumer's ``get()`` raises
  ``XDTProducerGone``, the error propagates to the *orchestrator* (the
  request process), which re-invokes the entry sub-workflow with the same
  arguments under fresh invocation ids (at-least-once at workflow level,
  at-most-once per id).
* Retries are bounded (``max_retries``), after which the error surfaces to
  the caller — identical to Step Functions fallback behaviour.

The blocking ``run(entry, payload)`` API is a thin wrapper: one ``submit``
plus driving the simulator to quiescence.

Memory at sweep scale
---------------------
``WorkflowEngine(records="columnar")`` switches invocation and request
bookkeeping to parallel arrays (:class:`InvocationLog`, :class:`RequestLog`):
O(a few dozen bytes) per invocation instead of an object each, and completed
:class:`WorkflowRequest` shells are not retained — million-request sweeps fit
in memory.  The default (``records="objects"``) keeps the legacy object lists.
"""
from __future__ import annotations

import dataclasses
from array import array
from types import GeneratorType
from typing import Any, Callable, Dict, Generator, List, Optional, Sequence, Tuple

from heapq import heappush as _heappush

from .cluster import Event, Simulator
from .clock import VirtualClock
from .errors import (
    InvocationReplayed,
    MediumUnavailable,
    RetriesExhausted,
    XDTError,
    XDTProducerGone,
)
from .refs import XDTRef
from .scheduler import ControlPlane, Deployment, ScalingPolicy
from .topology import as_coord
from .transfer import TransferEngine

_obj_new = object.__new__


@dataclasses.dataclass(slots=True)
class InvocationRecord:
    invocation_id: int
    function: str
    instance_id: int
    attempt: int
    status: str  # "ok" | "error"
    error_code: Optional[str] = None
    t_start: float = 0.0              # virtual time the invocation was steered
    t_end: float = 0.0                # virtual time it completed

    def overlaps(self, other: "InvocationRecord") -> bool:
        return self.t_start < other.t_end and other.t_start < self.t_end


class InvocationLog:
    """Columnar invocation records: parallel arrays, O(1) bookkeeping.

    Supports ``len``, indexing, and iteration (materializing
    :class:`InvocationRecord` views lazily) so introspection code written
    against the object list keeps working; the hot-path aggregates the
    engine and load generator need — count, billed seconds, per-function
    tallies — are maintained incrementally.
    """

    __slots__ = (
        "invocation_ids", "functions", "instance_ids", "statuses",
        "error_codes", "t_starts", "t_ends", "billed_s",
    )

    def __init__(self):
        self.invocation_ids = array("q")
        self.functions: List[str] = []
        self.instance_ids = array("q")
        self.statuses = array("b")        # 1 = ok, 0 = error
        self.error_codes: Dict[int, str] = {}   # sparse: index -> code
        self.t_starts = array("d")
        self.t_ends = array("d")
        self.billed_s = 0.0

    def append(
        self, invocation_id: int, function: str, instance_id: int,
        status: str, error_code: Optional[str], t_start: float, t_end: float,
    ) -> None:
        if error_code is not None:
            self.error_codes[len(self.invocation_ids)] = error_code
        self.invocation_ids.append(invocation_id)
        self.functions.append(function)
        self.instance_ids.append(instance_id)
        self.statuses.append(1 if status == "ok" else 0)
        self.t_starts.append(t_start)
        self.t_ends.append(t_end)
        self.billed_s += t_end - t_start

    def __len__(self) -> int:
        return len(self.invocation_ids)

    def __getitem__(self, i: int) -> InvocationRecord:
        if i < 0:
            i += len(self.invocation_ids)   # error_codes is keyed by position
        return InvocationRecord(
            invocation_id=self.invocation_ids[i],
            function=self.functions[i],
            instance_id=self.instance_ids[i],
            attempt=0,
            status="ok" if self.statuses[i] else "error",
            error_code=self.error_codes.get(i),
            t_start=self.t_starts[i],
            t_end=self.t_ends[i],
        )

    def __iter__(self):
        for i in range(len(self.invocation_ids)):
            yield self[i]


class RequestLog:
    """Columnar end-to-end request outcomes (columnar engine mode)."""

    __slots__ = ("request_ids", "latencies_s", "ok_flags")

    def __init__(self):
        self.request_ids = array("q")
        self.latencies_s = array("d")
        self.ok_flags = array("b")

    def append(self, request_id: int, latency_s: float, ok: bool) -> None:
        self.request_ids.append(request_id)
        self.latencies_s.append(latency_s)
        self.ok_flags.append(1 if ok else 0)

    def __len__(self) -> int:
        return len(self.request_ids)


class WorkflowRequest:
    """One end-to-end workflow execution tracked by the orchestrator.

    Doubles as its own retry-driving state machine (formerly a separate
    ``_RequestTask`` object): it waits on the entry invocation's handle,
    re-invokes under fresh invocation ids on :class:`XDTProducerGone`
    (bounded by ``max_retries``), and settles itself on any other outcome —
    one allocation per request instead of two.
    """

    __slots__ = (
        "request_id", "entry", "payload", "submitted_at", "status", "result",
        "error", "started_at", "finished_at", "attempts",
        "_sim", "_done", "_eng", "_retries", "_handle",
    )

    def __init__(
        self,
        request_id: int,
        entry: str,
        payload: Any,
        submitted_at: float,
        sim: Optional[Simulator] = None,
    ):
        self.request_id = request_id
        self.entry = entry
        self.payload = payload
        self.submitted_at = submitted_at
        self.status = "pending"   # pending | running | ok | error | failed
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.started_at = 0.0
        self.finished_at = 0.0
        self.attempts = 0
        self._sim = sim
        self._done: Optional[Event] = None
        self._eng: Any = None
        self._retries = 0
        self._handle: Any = None

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def done(self) -> Event:
        """Completion Event, materialized lazily: open-loop sweeps that poll
        the request log never allocate one; closed-loop clients that
        ``yield req.done`` get the exact old semantics."""
        d = self._done
        if d is None:
            d = self._done = Event(self._sim)
            if self.status in ("ok", "error", "failed"):
                d.set(self)
        return d

    def __repr__(self) -> str:
        return (
            f"WorkflowRequest(request_id={self.request_id}, "
            f"entry={self.entry!r}, status={self.status!r}, "
            f"attempts={self.attempts})"
        )

    # -- orchestration (the retry loop formerly in _RequestTask) ----------
    def _start(self, eng: "WorkflowEngine", presteered=None) -> None:
        self._eng = eng
        self.status = "running"
        self.started_at = eng.sim.now
        self._attempt(presteered)

    def _attempt(self, presteered=None) -> None:
        eng = self._eng
        while True:
            handle = _InvocationTask(eng, self.entry, self.payload,
                                     None, presteered)
            presteered = None          # retries re-steer at their own instant
            self.attempts += 1
            if not handle.fired:
                self._handle = handle
                handle._waiters.append(self)
                return
            if not self._settle(handle):
                return

    def __call__(self) -> None:
        handle, self._handle = self._handle, None
        if self._settle(handle):
            self._attempt()

    def _settle(self, handle: "AsyncResult") -> bool:
        """Consume one attempt's outcome; True means retry from the entry."""
        eng = self._eng
        err = handle.error
        if err is None:
            self.status, self.result = "ok", handle.value
        elif isinstance(err, (XDTProducerGone, MediumUnavailable)):
            if self._retries < eng.max_retries:
                # The producer instance is gone (its buffered objects died
                # with it) or the medium refused inside a degradation window.
                # Re-invoking from the entry function regenerates the objects
                # (paper §4.2.2) under fresh invocation ids.
                self._retries += 1
                eng.retry_total += 1
                if self._retries > eng.retry_max:
                    eng.retry_max = self._retries
                return True
            # Retry budget spent on transient errors: terminal *failed*
            # status in the log — priced for the work actually done — rather
            # than a raw exception aborting the whole sweep.
            self.status = "failed"
            self.error = RetriesExhausted(
                f"request {self.request_id}: retry budget "
                f"({eng.max_retries}) exhausted on {err.code}",
                cause=err,
            )
            eng.failed_requests += 1
            eng.failed_codes[err.code] = eng.failed_codes.get(err.code, 0) + 1
        else:
            self.status, self.error = "error", err
        self.finished_at = eng.sim.now
        eng._inflight_requests -= 1
        if eng._columnar:
            eng.request_log.append(
                self.request_id, self.finished_at - self.submitted_at,
                self.status == "ok",
            )
        d = self._done
        if d is not None:
            d.set(self)
        return False


class AsyncResult:
    """Handle for one concurrent sub-invocation (``ctx.call``).

    Resolution is intrinsic: the handle keeps its own ``fired`` flag and
    waiter list (state machines and fan-in counters append themselves
    directly), so the common await path allocates no :class:`Event` at all.
    ``done`` stays available for code that wants a real simulator event —
    it is materialized lazily and kept in sync with the handle.
    """

    __slots__ = ("function", "sim", "fired", "value", "error", "_waiters",
                 "_done")

    def __init__(self, sim: Simulator, function: str):
        self.function = function
        self.sim = sim
        self.fired = False
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self._waiters: Optional[list] = []
        self._done: Optional[Event] = None

    @property
    def done(self) -> Event:
        """A real simulator :class:`Event` mirroring this handle (lazy)."""
        d = self._done
        if d is None:
            d = self._done = Event(self.sim)
            if self.fired:
                d.set(self)
        return d

    def _resolve(self) -> None:
        """Fire the handle: wake direct waiters via the run queue (FIFO, at
        this virtual instant — exactly the old ``done.set(handle)``)."""
        self.fired = True
        waiters = self._waiters
        self._waiters = None
        if waiters:
            ready = self.sim._ready
            for w in waiters:
                ready.append(w)
        if self._done is not None:
            self._done.set(self)


class _FanIn:
    """Countdown waiter for ``yield [handles]`` fan-in.

    One of these sits on every unresolved handle of the group; each firing
    runs it as its own run-queue event (matching the per-handle ``dec``
    events of the ``all_of`` it replaced, so ``events_processed`` and event
    order are unchanged) and the last one re-queues the owning task — which
    then executes as a separate event, exactly like the old machine wakeup.
    """

    __slots__ = ("task", "remaining")

    def __init__(self, task: "_InvocationTask", remaining: int):
        self.task = task
        self.remaining = remaining

    def __call__(self) -> None:
        self.remaining -= 1
        if self.remaining == 0:
            task = self.task
            task.sim._ready.append(task)


class ChunkStream:
    """Producer->consumer chunk mailbox of ONE streamed logical object.

    The producer interleaves compute slices with :meth:`push` (a ref per
    chunk, already ``put`` on its resolved medium) and :meth:`seal` when the
    object is complete.  Consumers drain ``refs`` by cursor and ``yield``
    the :attr:`more` event to park until the next publication; ``first``
    fires on the very first chunk — the engine lowering registers
    data-triggered activation on it, so a consumer is steered the moment
    its input starts landing instead of after the producer's orchestration
    round-trip.  After ``seal`` the ``more`` event stays fired, so a late
    consumer drains the backlog without ever parking.
    """

    __slots__ = ("sim", "refs", "media", "objs", "sealed", "first", "_more",
                 "_open_producers", "gate")

    def __init__(self, sim: Simulator, n_producers: int = 1):
        self.sim = sim
        self.refs: List[XDTRef] = []
        self.media: List[str] = []
        #: per-chunk logical-object token: chunks sharing a token are ranges
        #: of ONE object, so storage requests bill once per (token, medium)
        self.objs: List[Any] = []
        self.sealed = False
        self.first = Event(sim)
        self._more = Event(sim)
        # fan-in seal: a wave edge's consumer stream is fed by every
        # producer instance; the stream seals when the LAST producer does
        self._open_producers = n_producers
        #: credit-based backpressure hook (``Edge(max_inflight_chunks=...)``):
        #: when set, the consumer reports each drained chunk so the producer's
        #: credit window can release — ``None`` keeps the drain unconditional
        self.gate = None

    @property
    def more(self) -> Event:
        """The event the NEXT push (or seal) fires; permanently fired once
        sealed, so post-seal waits resume immediately."""
        return self._more

    def push(self, ref: XDTRef, medium: str, obj: Any) -> None:
        if self.sealed:
            raise RuntimeError("push() on a sealed ChunkStream")
        self.refs.append(ref)
        self.media.append(medium)
        self.objs.append(obj)
        if not self.first.fired:
            self.first.set()
        ev, self._more = self._more, Event(self.sim)
        ev.set()

    def push_span(self, refs: Sequence[XDTRef], medium: str, obj: Any) -> None:
        """Publish a same-instant run of chunks of ONE object with a single
        mailbox rotation: the lists extend columnar and waiting consumers
        wake once for the whole span instead of once per chunk.  Semantics
        are identical to ``push`` per ref — a parked consumer is appended to
        the run queue exactly once either way."""
        if self.sealed:
            raise RuntimeError("push_span() on a sealed ChunkStream")
        n = len(refs)
        self.refs.extend(refs)
        self.media.extend([medium] * n)
        self.objs.extend([obj] * n)
        if not self.first.fired:
            self.first.set()
        ev, self._more = self._more, Event(self.sim)
        ev.set()

    def seal(self) -> None:
        self._open_producers -= 1
        if self._open_producers > 0:
            return
        self.sealed = True
        if not self.first.fired:
            self.first.set()
        self._more.set()                # stays fired for late consumers


class CreditGate:
    """Producer-side credit window for ONE streaming edge's sender.

    ``Edge(max_inflight_chunks=w)`` bounds sender memory: at most ``w``
    instance-resident chunks may be published-but-undrained at once.  The
    producer registers each resident chunk via :meth:`publish` and parks on
    :meth:`wait` while :attr:`full`; consumers report every drained chunk
    through :meth:`on_pull`, which releases the credit once the chunk's last
    retrieval lands (broadcast chunks hold their credit until every consumer
    has pulled).  Durable chunks never register — the store, not the sender,
    holds them — so a pressure-spilled stream runs credit-free.  Refs the
    gate never registered are ignored, so consumers can report uncondition-
    ally.  Deadlock-free: a full window implies undrained chunks, and every
    streaming consumer is spawned (or data-trigger armed) before production
    starts, so someone is always able to drain.
    """

    __slots__ = ("sim", "window", "outstanding", "_event", "_pulls")

    def __init__(self, sim: Simulator, window: int):
        self.sim = sim
        self.window = window
        self.outstanding = 0
        self._event: Optional[Event] = None
        # id(ref) -> retrievals still holding the chunk's credit; keyed by
        # id because refs stay alive in the stream's columnar lists
        self._pulls: Dict[int, int] = {}

    @property
    def full(self) -> bool:
        return self.outstanding >= self.window

    def wait(self) -> Event:
        """Event firing on the next credit release; yield it while full."""
        ev = self._event
        if ev is None or ev.fired:
            ev = self._event = Event(self.sim)
        return ev

    def publish(self, ref: Any, n_retrievals: int) -> None:
        self.outstanding += 1
        self._pulls[id(ref)] = n_retrievals

    def on_pull(self, ref: Any) -> None:
        key = id(ref)
        rem = self._pulls.get(key)
        if rem is None:
            return
        if rem <= 1:
            del self._pulls[key]
            self.outstanding -= 1
            ev = self._event
            if ev is not None and not ev.fired:
                ev.set()
        else:
            self._pulls[key] = rem - 1


class Context:
    """Per-invocation SDK handle given to user handlers."""

    __slots__ = ("_engine", "_debt", "function", "attempt", "instance")

    def __init__(
        self,
        engine: "WorkflowEngine",
        function: str,
        attempt: int,
        instance=None,
    ):
        self._engine = engine
        self._debt = 0.0              # virtual seconds owed at next pay point
        self.function = function
        self.attempt = attempt
        self.instance = instance

    # -- debt ------------------------------------------------------------
    def _take_debt(self) -> float:
        d, self._debt = self._debt, 0.0
        return d

    def sleep(self, seconds: float) -> None:
        """Spend ``seconds`` of virtual compute time in this invocation."""
        self._debt += max(0.0, float(seconds))

    # XDT API (paper Table 1)
    def invoke(self, fn_name: str, obj: Any) -> Any:
        """Blocking sub-invocation: the caller stalls until the callee is
        done, and inherits the callee's virtual-time debt."""
        return self._engine._invoke_inline(fn_name, obj, parent=self)

    def call(
        self, fn_name: str, obj: Any, affinity: Optional[Tuple[int, ...]] = None
    ) -> AsyncResult:
        """Concurrent sub-invocation.  Generator handlers ``yield`` the
        handle (or a list of handles) to fan-in.

        ``affinity`` is a placement hint forwarded to the callee's
        ``Deployment.steer``: pass this invocation's own coords
        (``ctx.instance.coords``) to ask the activator to land the callee on
        the caller's node when slots allow — the graph optimizer's
        co-placement pass rides this to make XDT pulls instance-local.
        Accepts a plain tuple or a typed
        :class:`~repro.core.topology.Coord` (whose zone the steer can fall
        back to when the exact instance is busy)."""
        return _InvocationTask(self._engine, fn_name, obj, as_coord(affinity))

    def put(
        self, obj: Any, n_retrievals: int = 1, backend: Optional[str] = None
    ) -> XDTRef:
        """Buffer ``obj``; ``backend`` overrides the engine's default medium
        for this one object (per-edge routing — the ref remembers its
        medium, so the consumer's ``get`` needs no extra argument)."""
        return self._engine.transfer.put(obj, n_retrievals, backend=backend)

    def get(self, ref: XDTRef, local: bool = False) -> Any:
        """One retrieval.  ``local=True`` marks this consumer as co-placed
        with the producer (scheduling honored an affinity hint): pulls of
        instance-resident media are modeled at shared-memory speed."""
        stats = self._engine.transfer.stats
        before = stats.modeled_seconds
        obj = self._engine.transfer.get(ref, local=local)
        # the modeled pull latency becomes virtual time owed by this function
        self._debt += stats.modeled_seconds - before
        return obj

    def put_chunk(
        self,
        obj: Any,
        n_retrievals: int = 1,
        backend: Optional[str] = None,
        bill_put: bool = True,
    ) -> XDTRef:
        """Publish one chunk of a streamed logical object.

        Same medium semantics as :meth:`put`; ``bill_put=False`` suppresses
        the per-request PUT fee on service backends (multipart upload: one
        logical PUT per object, the first chunk pays it)."""
        return self._engine.transfer.put_chunk(
            obj, n_retrievals, backend=backend, bill_put=bill_put
        )

    def get_chunk(self, ref: XDTRef, local: bool = False, bill_get: bool = False) -> Any:
        """Pull one chunk; the modeled latency accrues as debt exactly like
        :meth:`get`.  ``bill_get=False`` (default) folds the request into the
        object's single ranged GET per medium — pass ``True`` on the first
        chunk pulled from each medium."""
        stats = self._engine.transfer.stats
        before = stats.modeled_seconds
        obj = self._engine.transfer.get_chunk(ref, local=local, bill_get=bill_get)
        self._debt += stats.modeled_seconds - before
        return obj

    def put_chunk_span(
        self,
        obj: Any,
        count: int,
        n_retrievals: int = 1,
        backend: Optional[str] = None,
        bill_put: bool = True,
    ) -> List[XDTRef]:
        """Publish a same-instant span of ``count`` chunks of one streamed
        object in a single kernel call (see ``TransferEngine.put_chunk_span``
        — refs built columnar, PUT billing coalesced once per span)."""
        return self._engine.transfer.put_chunk_span(
            obj, count, n_retrievals, backend=backend, bill_put=bill_put
        )

    def get_chunk_span(
        self, refs: Sequence[XDTRef], local: bool = False,
        bill_first: bool = False,
    ) -> List[Any]:
        """Drain a run of same-(object, medium) chunks in one kernel call;
        the modeled latency accrues as debt chunk by chunk (replayed from
        the kernel's per-chunk marks) so the total is bit-identical to the
        scalar drain's float-op sequence."""
        stats = self._engine.transfer.stats
        prev = stats.modeled_seconds
        marks: List[float] = []
        out = self._engine.transfer.get_chunk_span(
            refs, local=local, bill_first=bill_first, marks=marks
        )
        for m in marks:
            self._debt += m - prev
            prev = m
        return out

    # collective conveniences built from the primitives (paper §7.1)
    def scatter(self, fn_name: str, objs: Sequence[Any]) -> List[Any]:
        return [self.invoke(fn_name, o) for o in objs]

    def scatter_async(self, fn_name: str, objs: Sequence[Any]) -> List[AsyncResult]:
        """Overlapping scatter: spawn all, fan-in with ``yield handles``."""
        return [self.call(fn_name, o) for o in objs]

    def broadcast(self, fn_name: str, obj: Any, fan: int) -> List[Any]:
        ref = self.put(obj, n_retrievals=fan)
        return [self.invoke(fn_name, ref) for _ in range(fan)]

    def gather(self, refs: Sequence[XDTRef]) -> List[Any]:
        return [self.get(r) for r in refs]


class _InvocationTask(AsyncResult):
    """One control-plane-mediated invocation as a callable state machine.

    Replaces the per-invocation generator frame (steer -> cold-start wait ->
    control-plane hop -> handler -> debt -> record) on the hot path.  It
    produces the *exact* heap-entry sequence of the generator it replaced —
    the same pushes, at the same timestamps, taking the same ``seq`` numbers,
    with the separate wait/ctrl/debt timeouts kept separate (merging them
    would re-associate the float sums and shift timestamps by ulps) — so
    fixed-seed per-request latencies are bit-identical while each event costs
    no generator resume, no Process/Event wrapper, and no StopIteration.

    The task *is* its own :class:`AsyncResult`: ``ctx.call`` returns the task
    object directly, so an invocation costs one allocation, not a
    task + handle pair.  Resolution/waiter semantics are inherited unchanged.

    Generator *handlers* still interleave at every yield: the drive loop that
    used to live in ``WorkflowEngine._drive`` is inlined as phases 3-7.
    """

    __slots__ = (
        "eng", "payload", "fn", "svc_time", "invocation_id",
        "deployment", "instance", "ctx", "t0", "phase", "gen", "send",
        "throw_", "pending",
    )

    # phases: what to do when the simulator calls us back
    # 0 cold-start wait elapsed -> push the ctrl hop
    # 1 ctrl hop elapsed        -> run the handler
    # 2 final debt elapsed      -> record + release + resolve the handle
    # 3 drive-loop debt elapsed -> dispatch the pending yielded value
    # 4 numeric yield elapsed   -> resume the generator handler
    # 5 awaited AsyncResult set -> resume with its value/error
    # 6 awaited fan-in group set-> resume with values/first error
    # 7 awaited raw Event set   -> resume with its value

    def __init__(self, eng: "WorkflowEngine", fn_name: str, payload: Any,
                 affinity=None, presteered=None):
        # intrinsic handle state (AsyncResult fields, inlined — no super())
        self.function = fn_name
        sim = self.sim = eng.sim
        self.fired = False
        self.value = None
        self.error = None
        self._waiters = []
        self._done = None
        # task state
        self.eng = eng
        self.payload = payload
        self.gen = None
        self.send = None
        self.throw_ = None
        self.pending = None
        try:
            entry = eng._dispatch.get(fn_name)
            if entry is None:
                raise KeyError(f"unknown function {fn_name!r}")
            self.fn, dep, self.svc_time = entry
            self.deployment = dep
            eng._invocation_watermark = iid = eng._invocation_watermark + 1
            self.invocation_id = iid
            if presteered is not None:   # batch-submitted: already steered
                self.instance, wait = presteered
            elif type(dep) is Deployment:
                # inlined Deployment.steer: one clock read + due-guarded
                # reap/mature + one pick — bit-identical to dep.steer(),
                # one frame cheaper per invocation
                vs = dep._vsim
                now = dep.clock() if vs is None else vs.now
                exp = dep._expiry
                if exp and exp[0][0] < now:
                    dep._reap_expired(now)
                warm = dep._warming
                if warm and warm[0][0] <= now:
                    dep._mature_warming(now)
                self.instance, wait = dep._steer_one(now, affinity)
            else:                        # custom deployment: keep the API
                self.instance, wait = dep.steer(affinity)
            self.t0 = sim.now
            if wait > 0:               # activator buffers across cold start
                self.phase = 0
                sim._seq = seq = sim._seq + 1
                _heappush(sim._heap, (sim.now + wait, seq, self))
                return
            ctrl = eng._ctrl_latency   # inlined _push_ctrl (warm common case)
            if ctrl > 0:
                self.phase = 1
                sim._seq = seq = sim._seq + 1
                _heappush(sim._heap, (sim.now + ctrl, seq, self))
            else:
                self._run_handler()
        except BaseException as e:     # pre-steer failure: nothing to record
            self.error = e
            self._resolve()

    def __call__(self) -> None:
        ph = self.phase                # ordered by observed frequency
        if ph == 1:
            self._run_handler()
        elif ph == 2:
            self._finish()
        elif ph == 5:
            h, self.pending = self.pending, None
            if h.error is not None:
                self.throw_ = h.error
            else:
                self.send = h.value
            self._drive_loop()
        elif ph == 6:
            hs, self.pending = self.pending, None
            errs = [h.error for h in hs if h.error is not None]
            if errs:
                self.throw_ = errs[0]
            else:
                self.send = [h.value for h in hs]
            self._drive_loop()
        elif ph == 3:
            y, self.pending = self.pending, None
            try:
                if not self._dispatch_yield(y):
                    return
            except BaseException as e:
                self._fail(e)
                return
            self._drive_loop()
        elif ph == 4:
            self._drive_loop()
        elif ph == 0:
            self._push_ctrl()
        else:
            ev, self.pending = self.pending, None
            self.send = ev.value
            self._drive_loop()

    def _push_ctrl(self) -> None:
        ctrl = self.eng._ctrl_latency
        if ctrl > 0:
            self.phase = 1
            sim = self.sim
            sim._seq = seq = sim._seq + 1
            _heappush(sim._heap, (sim.now + ctrl, seq, self))
        else:
            self._run_handler()

    def _run_handler(self) -> None:
        eng = self.eng
        # Context constructed via object.__new__ + direct stores: same five
        # assignments its __init__ would do, minus the call frame
        ctx = self.ctx = _obj_new(Context)
        ctx._engine = eng
        ctx._debt = 0.0
        ctx.function = self.function
        ctx.attempt = 0
        ctx.instance = self.instance
        try:
            out = self.fn(ctx, self.payload)
        except BaseException as e:
            self._fail(e)
            return
        if type(out) is GeneratorType:
            self.gen = out
            self._drive_loop()
            return
        self.pending = out
        debt = ctx._debt + self.svc_time
        ctx._debt = 0.0
        if debt > 0:
            self.phase = 2
            sim = self.sim
            sim._seq = seq = sim._seq + 1
            _heappush(sim._heap, (sim.now + debt, seq, self))
        else:
            self._finish()

    def _drive_loop(self) -> None:
        """Step the generator handler, paying debt at every yield boundary."""
        gen = self.gen
        while True:
            try:
                if self.throw_ is not None:
                    t, self.throw_ = self.throw_, None
                    yielded = gen.throw(t)
                else:
                    s, self.send = self.send, None
                    yielded = gen.send(s)
            except StopIteration as stop:
                ctx = self.ctx
                debt = ctx._debt + self.svc_time
                ctx._debt = 0.0
                self.pending = stop.value
                if debt > 0:
                    self.phase = 2
                    sim = self.sim
                    sim._seq = seq = sim._seq + 1
                    _heappush(sim._heap, (sim.now + debt, seq, self))
                else:
                    self._finish()
                return
            except BaseException as e:
                self._fail(e)
                return
            ctx = self.ctx
            debt = ctx._debt
            if debt > 0:
                ctx._debt = 0.0
                self.pending = yielded
                self.phase = 3
                sim = self.sim
                sim._seq = seq = sim._seq + 1
                _heappush(sim._heap, (sim.now + debt, seq, self))
                return
            try:
                if not self._dispatch_yield(yielded):
                    return             # suspended on a heap entry or event
            except BaseException as e:
                self._fail(e)
                return

    def _dispatch_yield(self, yielded) -> bool:
        """Act on one value yielded by a generator handler.

        Returns True when the drive loop can continue immediately (the
        awaited event had already fired — the trampoline case of the old
        ``Simulator._step``), False when this task suspended.
        """
        sim = self.sim
        if isinstance(yielded, AsyncResult):   # most common: await a call
            if yielded.fired:
                if yielded.error is not None:
                    self.throw_ = yielded.error
                else:
                    self.send = yielded.value
                return True
            self.pending = yielded
            self.phase = 5
            yielded._waiters.append(self)
            return False
        if isinstance(yielded, (int, float)):
            v = float(yielded)
            self.phase = 4
            sim._seq = seq = sim._seq + 1
            _heappush(sim._heap, (sim.now + (v if v > 0.0 else 0.0), seq, self))
            return False
        if isinstance(yielded, (list, tuple)) and all(
            isinstance(h, AsyncResult) for h in yielded
        ):
            n_pending = 0
            for h in yielded:
                if not h.fired:
                    n_pending += 1
            if n_pending == 0:
                errs = [h.error for h in yielded if h.error is not None]
                if errs:
                    self.throw_ = errs[0]
                else:
                    self.send = [h.value for h in yielded]
                return True
            self.pending = yielded
            self.phase = 6
            fan = _FanIn(self, n_pending)
            for h in yielded:
                if not h.fired:
                    h._waiters.append(fan)
            return False
        if isinstance(yielded, Event):
            # raw simulator event: lets handlers wait on external completion
            # signals (e.g. the disaggregated server bridging real decode
            # completion into virtual time)
            if yielded.fired:
                self.send = yielded.value
                return True
            self.pending = yielded
            self.phase = 7
            yielded._waiters.append(self)
            return False
        raise TypeError(
            f"handler {self.ctx.function!r} yielded {type(yielded).__name__}; "
            "yield seconds, an AsyncResult, a list of AsyncResults, "
            "or a simulator Event"
        )

    def _finish(self) -> None:
        eng = self.eng
        self.value, self.pending = self.pending, None
        t1 = self.sim.now
        log = eng._ilog
        if log is not None:
            # inlined InvocationLog.append for the ok/no-error-code case:
            # same column order, no method frame or status-string compare
            log.invocation_ids.append(self.invocation_id)
            log.functions.append(self.function)
            log.instance_ids.append(self.instance.instance_id)
            log.statuses.append(1)
            log.t_starts.append(self.t0)
            log.t_ends.append(t1)
            log.billed_s += t1 - self.t0
        else:
            eng._record(
                self.invocation_id, self.function, self.instance.instance_id,
                "ok", None, self.t0, t1,
            )
        self.deployment.release(self.instance.instance_id)
        self._resolve()

    def _fail(self, e: BaseException) -> None:
        """Handler raised after steer: record the error, then surface it."""
        code = e.code if isinstance(e, XDTError) else None
        eng = self.eng
        eng._record(
            self.invocation_id, self.function, self.instance.instance_id,
            "error", code, self.t0, self.sim.now,
        )
        self.deployment.release(self.instance.instance_id)
        self.error = e
        self._resolve()


class WorkflowEngine:
    """Executes function DAGs concurrently with at-most-once semantics."""

    def __init__(
        self,
        transfer: Optional[TransferEngine] = None,
        control_plane: Optional[ControlPlane] = None,
        max_retries: int = 2,
        simulator: Optional[Simulator] = None,
        seed: int = 0,
        backend: str = "xdt",
        records: str = "objects",
    ):
        self.sim = simulator if simulator is not None else Simulator(seed=seed)
        self.clock = VirtualClock(self.sim)
        # `backend` picks the default transfer medium; pass `transfer` to
        # bring your own engine (it should share this engine's clock, or
        # GB-second accounting runs on wall time while requests run virtual).
        if transfer is not None:
            self.transfer = transfer
        else:
            # The registry's blocking flow control is wall-clock: on the
            # single-threaded virtual-time engine a blocked put() can never
            # be unblocked (the consumer that would free a slot runs on this
            # same thread), so the default 256-slot budget deadlocked sweeps
            # with a few hundred requests in flight.  Size the buffer budget
            # for sweep-scale concurrency instead; backpressure at this
            # layer is modeled in virtual time, not thread-blocked.
            from .buffers import BufferRegistry

            registry = BufferRegistry(
                max_slots=1 << 20, max_bytes=1 << 40, clock=self.clock,
                threadsafe=False,
            )
            self.transfer = TransferEngine(
                backend, registry=registry, clock=self.clock
            )
        self.control = (
            control_plane if control_plane is not None
            else ControlPlane(clock=self.clock)
        )
        self.functions: Dict[str, Callable[[Context, Any], Any]] = {}
        self.service_times: Dict[str, float] = {}
        self._deployments: Dict[str, Any] = {}   # per-function direct dispatch
        # one-hit dispatch cache: name -> (handler, deployment, service_time)
        # — the invocation hot path pays one dict probe instead of three
        self._dispatch: Dict[str, Tuple[Any, Any, float]] = {}
        self.max_retries = max_retries
        # fault/SLO observability (read by faults.SLOGuard): total retry
        # re-invocations, the worst per-request retry count, and terminal
        # failures bucketed by the transient error code that exhausted them
        self.retry_total = 0
        self.retry_max = 0
        self.failed_requests = 0
        self.failed_codes: Dict[str, int] = {}
        # high-watermark at-most-once: ids are issued monotonically; every id
        # <= the watermark is spent and can never be executed again
        self._invocation_watermark = 0
        self._request_counter = 0
        self._inflight_requests = 0
        if records not in ("objects", "columnar"):
            raise ValueError(f"records must be 'objects' or 'columnar', got {records!r}")
        self._columnar = records == "columnar"
        self.records: Any = InvocationLog() if self._columnar else []
        self.requests: List[WorkflowRequest] = []
        self.request_log = RequestLog() if self._columnar else None
        # prebound recorder: columnar appends go straight to the log with no
        # dispatch frame in between (the signatures match by construction)
        if self._columnar:
            self._record = self.records.append
        # the columnar log, or None: _finish inlines the append when set
        self._ilog = self.records if self._columnar else None
        # net constants are frozen per engine: cache the control-plane hop
        self._ctrl_latency = self.transfer.net.ctrl_plane_latency

    # -- registration ----------------------------------------------------------
    def register(
        self,
        name: str,
        handler: Callable[[Context, Any], Any],
        policy: Optional[ScalingPolicy] = None,
        service_time: float = 0.0,
        placer: Optional[Callable[[int], Tuple[int, ...]]] = None,
    ) -> None:
        """Register ``handler`` under ``name``.  ``service_time`` is the
        function's intrinsic compute duration in virtual seconds (on top of
        any ``ctx.sleep``/transfer debt it accrues).  ``placer`` maps
        instance ids to placement coords (e.g. zone-carrying
        :class:`~repro.core.topology.Coord` under a topology); default is
        the scheduler's ``(i,)``."""
        self.functions[name] = handler
        self.service_times[name] = service_time
        dep = self.control.register(
            name, policy or ScalingPolicy(max_instances=16), placer
        )
        # rate-driven autoscalers need requests-per-instance capacity before
        # the first completions exist; the registered service time is the
        # natural prior (no-op for telemetry-free legacy deployments)
        dep.seed_holding_estimate(service_time)
        self._deployments[name] = dep
        self._dispatch[name] = (handler, dep, service_time)

    # -- orchestrator ------------------------------------------------------------
    def submit(self, entry: str, payload: Any) -> WorkflowRequest:
        """Enqueue one workflow request; drive with ``drain()``/``run()``."""
        if entry not in self.functions:
            raise KeyError(f"unknown function {entry!r}")
        self._request_counter = rid = self._request_counter + 1
        req = WorkflowRequest(rid, entry, payload, self.sim.now, self.sim)
        self._inflight_requests += 1
        if not self._columnar:
            # columnar mode does not retain completed request shells; the
            # outcome lands in `request_log` instead
            self.requests.append(req)
        req._start(self)
        return req

    def submit_batch(self, entry: str, payloads: Sequence[Any]) -> List[WorkflowRequest]:
        """Submit many same-entry requests arriving at this virtual instant.

        The batched-arrival kernel behind the trace replay driver: one
        same-timestamp bucket of arrivals becomes one ``steer_batch`` against
        the deployment (a single reap/mature pass amortized over the bucket)
        followed by the per-request state machines.  Equivalent to calling
        :meth:`submit` once per payload — the per-request heap entries are
        identical — just cheaper per arrival.
        """
        if entry not in self.functions:
            raise KeyError(f"unknown function {entry!r}")
        # Batch-steer the whole bucket first: every request in the bucket
        # would have steered at this same instant anyway (steering happens at
        # submit time; the entry deployment is untouched in between), so one
        # reap/mature pass serves all of them and the per-arrival picks are
        # bit-identical to sequential submits.
        steers = self._deployments[entry].steer_batch(len(payloads))
        out = []
        for payload, presteered in zip(payloads, steers):
            self._request_counter = rid = self._request_counter + 1
            req = WorkflowRequest(rid, entry, payload, self.sim.now, self.sim)
            self._inflight_requests += 1
            if not self._columnar:
                self.requests.append(req)
            req._start(self, presteered)
            out.append(req)
        return out

    def drain(self) -> List[WorkflowRequest]:
        """Run the simulator until every submitted request completed."""
        self.sim.run()
        if self._inflight_requests:
            pending = [
                r for r in self.requests if r.status in ("pending", "running")
            ] or self._inflight_requests
            raise RuntimeError(f"workflow deadlock: {pending}")
        return self.requests

    def run(self, entry: str, payload: Any) -> Any:
        """Blocking wrapper: submit one request and drive it to completion;
        on XDTProducerGone the orchestrator re-invokes the entry sub-workflow
        with the original arguments, up to ``max_retries`` times."""
        req = self.submit(entry, payload)
        self.sim.run()
        if req.error is not None:    # "error" and terminal "failed" alike
            raise req.error
        return req.result

    # -- execution ---------------------------------------------------------------
    def _next_invocation_id(self) -> int:
        invocation_id = self._invocation_watermark + 1
        if invocation_id <= self._invocation_watermark:  # pragma: no cover
            raise InvocationReplayed(f"id {invocation_id} already executed")
        self._invocation_watermark = invocation_id
        return invocation_id

    def _record(
        self, invocation_id: int, fn_name: str, instance_id: int,
        status: str, code: Optional[str], t_start: float, t_end: float,
    ) -> None:
        # objects mode only; columnar engines bind InvocationLog.append
        # directly over this method in __init__
        self.records.append(
            InvocationRecord(
                invocation_id, fn_name, instance_id, 0,
                status, code, t_start=t_start, t_end=t_end,
            )
        )

    def _spawn_invocation(
        self,
        fn_name: str,
        payload: Any,
        affinity: Optional[Tuple[int, ...]] = None,
        presteered: Optional[Tuple[Any, float]] = None,
    ) -> AsyncResult:
        """Start one control-plane-mediated invocation (state-machine task).

        The returned handle *is* the task object (an :class:`AsyncResult`
        subclass) — one allocation per invocation."""
        return _InvocationTask(self, fn_name, payload, affinity, presteered)

    def _invoke_inline(self, fn_name: str, payload: Any, parent: Context) -> Any:
        """Blocking sub-invocation from inside a running handler.

        Executes at the caller's current virtual instant; the callee's
        cold-start wait, control-plane hop, transfer debt, and service time
        are charged to the *caller's* debt (blocking-chain billing, the
        vSwarm semantics the cost model assumes).
        """
        fn = self.functions.get(fn_name)
        if fn is None:
            raise KeyError(f"unknown function {fn_name!r}")
        invocation_id = self._next_invocation_id()
        deployment = self._deployments[fn_name]
        instance, wait = deployment.steer()
        t0 = self.sim.now
        parent._debt += wait + self._ctrl_latency
        ctx = Context(self, fn_name, attempt=0, instance=instance)
        status, code = "ok", None
        try:
            out = fn(ctx, payload)
            if type(out) is GeneratorType:
                raise TypeError(
                    f"generator handler {fn_name!r} cannot be invoked inline; "
                    "use ctx.call() / scatter_async() / submit()"
                )
            parent._debt += ctx._take_debt() + self.service_times[fn_name]
            return out
        except XDTError as e:
            status, code = "error", e.code
            raise
        except BaseException:
            status = "error"               # foreign errors: no stable code
            raise
        finally:
            self._record(
                invocation_id, fn_name, instance.instance_id,
                status, code, t0, self.sim.now,
            )
            deployment.release(instance.instance_id)

    # -- introspection -----------------------------------------------------------
    def executed_count(self, fn_name: Optional[str] = None) -> int:
        if self._columnar:
            if fn_name is None:
                return len(self.records)
            return self.records.functions.count(fn_name)
        return sum(
            1 for r in self.records if fn_name is None or r.function == fn_name
        )

    def billed_virtual_seconds(self) -> float:
        """Sum of per-invocation (t_end - t_start) across all records."""
        if self._columnar:
            return self.records.billed_s
        return sum(r.t_end - r.t_start for r in self.records)

    def assert_at_most_once(self) -> None:
        """Invariant: no invocation id appears twice in the records."""
        if self._columnar:
            ids = list(self.records.invocation_ids)
        else:
            ids = [r.invocation_id for r in self.records]
        assert len(ids) == len(set(ids)), "invocation id executed more than once"

    def latency_records(self) -> List[Tuple[int, float]]:
        """(request_id, end-to-end latency in virtual seconds) per request."""
        if self._columnar:
            log = self.request_log
            # the log appends in completion order; report in request-id
            # (submission) order like the legacy object list
            return sorted(zip(log.request_ids, log.latencies_s))
        return [
            (r.request_id, r.latency_s)
            for r in self.requests
            if r.status in ("ok", "error", "failed")
        ]
