"""Event-driven workflow engine: concurrent function DAGs on virtual time.

A workflow is a DAG of named functions.  Each function is user logic with the
signature ``handler(ctx, payload) -> payload`` where ``ctx`` exposes the XDT
API (paper Table 1): ``ctx.invoke(fn, obj)``, ``ctx.put(obj, n) -> ref``,
``ctx.get(ref) -> obj``.  Placement is delegated to the control plane
(:mod:`repro.core.scheduler`), transfers to a :class:`TransferEngine`.

Execution model
---------------
The engine runs on the discrete-event :class:`~repro.core.cluster.Simulator`:
scheduler, transfer accounting, and per-request latency records all share one
:class:`~repro.core.clock.VirtualClock`.  Many workflow *requests* can be in
flight at once (``submit`` + ``drain``), their invocations overlap in virtual
time, and cold starts gate execution exactly as the autoscaler decides.

Two handler styles:

* **Plain handlers** (``def h(ctx, payload): return ...``) run atomically at
  one virtual instant; the virtual time they owe — cold-start waits, modeled
  transfer seconds from ``ctx.get`` (puts are producer-local buffering and
  charge nothing; the through-storage round-trip is billed at the pull),
  ``ctx.sleep`` compute, the function's registered ``service_time`` —
  accrues as *debt* that the engine pays as one timeout after the handler
  body.  ``ctx.invoke`` is a blocking inline sub-invocation, as before.
* **Generator handlers** (``def h(ctx, payload): ... yield ...``) interleave
  with the rest of the cluster at every yield.  Yield a number to spend
  compute seconds, an :class:`AsyncResult` from ``ctx.call(fn, obj)`` to
  await one concurrent sub-invocation, or a list of them for fan-out/fan-in
  that actually overlaps.

Semantics (paper §4.2.2), unchanged from the synchronous engine:

* **At-most-once per invocation id** — the engine records executed ids and
  refuses replays (:class:`InvocationReplayed`).
* **Producer-death recovery** — if a consumer's ``get()`` raises
  ``XDTProducerGone``, the error propagates to the *orchestrator* (the
  request process), which re-invokes the entry sub-workflow with the same
  arguments under fresh invocation ids (at-least-once at workflow level,
  at-most-once per id).
* Retries are bounded (``max_retries``), after which the error surfaces to
  the caller — identical to Step Functions fallback behaviour.

The blocking ``run(entry, payload)`` API is a thin wrapper: one ``submit``
plus driving the simulator to quiescence.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
from typing import Any, Callable, Dict, Generator, List, Optional, Sequence, Tuple

from .cluster import Simulator
from .clock import VirtualClock
from .errors import XDTError, XDTProducerGone
from .refs import XDTRef
from .scheduler import ControlPlane, ScalingPolicy
from .transfer import TransferEngine


@dataclasses.dataclass
class InvocationRecord:
    invocation_id: int
    function: str
    instance_id: int
    attempt: int
    status: str  # "ok" | "error"
    error_code: Optional[str] = None
    t_start: float = 0.0              # virtual time the invocation was steered
    t_end: float = 0.0                # virtual time it completed

    def overlaps(self, other: "InvocationRecord") -> bool:
        return self.t_start < other.t_end and other.t_start < self.t_end


@dataclasses.dataclass
class WorkflowRequest:
    """One end-to-end workflow execution tracked by the orchestrator."""

    request_id: int
    entry: str
    payload: Any
    submitted_at: float
    status: str = "pending"           # pending | running | ok | error
    result: Any = None
    error: Optional[BaseException] = None
    started_at: float = 0.0
    finished_at: float = 0.0
    attempts: int = 0
    done: Any = None                  # simulator Event, set on completion

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


class AsyncResult:
    """Handle for one concurrent sub-invocation (``ctx.call``)."""

    def __init__(self, sim: Simulator, function: str):
        self.function = function
        self.done = sim.event()
        self.value: Any = None
        self.error: Optional[BaseException] = None


class Context:
    """Per-invocation SDK handle given to user handlers."""

    def __init__(
        self,
        engine: "WorkflowEngine",
        function: str,
        attempt: int,
        instance=None,
    ):
        self._engine = engine
        self._debt = 0.0              # virtual seconds owed at next pay point
        self.function = function
        self.attempt = attempt
        self.instance = instance

    # -- debt ------------------------------------------------------------
    def _take_debt(self) -> float:
        d, self._debt = self._debt, 0.0
        return d

    def sleep(self, seconds: float) -> None:
        """Spend ``seconds`` of virtual compute time in this invocation."""
        self._debt += max(0.0, float(seconds))

    # XDT API (paper Table 1)
    def invoke(self, fn_name: str, obj: Any) -> Any:
        """Blocking sub-invocation: the caller stalls until the callee is
        done, and inherits the callee's virtual-time debt."""
        return self._engine._invoke_inline(fn_name, obj, parent=self)

    def call(self, fn_name: str, obj: Any) -> AsyncResult:
        """Concurrent sub-invocation.  Generator handlers ``yield`` the
        handle (or a list of handles) to fan-in."""
        return self._engine._spawn_invocation(fn_name, obj)

    def put(self, obj: Any, n_retrievals: int = 1) -> XDTRef:
        return self._engine.transfer.put(obj, n_retrievals)

    def get(self, ref: XDTRef) -> Any:
        before = self._engine.transfer.stats.modeled_seconds
        obj = self._engine.transfer.get(ref)
        # the modeled pull latency becomes virtual time owed by this function
        self._debt += self._engine.transfer.stats.modeled_seconds - before
        return obj

    # collective conveniences built from the primitives (paper §7.1)
    def scatter(self, fn_name: str, objs: Sequence[Any]) -> List[Any]:
        return [self.invoke(fn_name, o) for o in objs]

    def scatter_async(self, fn_name: str, objs: Sequence[Any]) -> List[AsyncResult]:
        """Overlapping scatter: spawn all, fan-in with ``yield handles``."""
        return [self.call(fn_name, o) for o in objs]

    def broadcast(self, fn_name: str, obj: Any, fan: int) -> List[Any]:
        ref = self.put(obj, n_retrievals=fan)
        return [self.invoke(fn_name, ref) for _ in range(fan)]

    def gather(self, refs: Sequence[XDTRef]) -> List[Any]:
        return [self.get(r) for r in refs]


class WorkflowEngine:
    """Executes function DAGs concurrently with at-most-once semantics."""

    def __init__(
        self,
        transfer: Optional[TransferEngine] = None,
        control_plane: Optional[ControlPlane] = None,
        max_retries: int = 2,
        simulator: Optional[Simulator] = None,
        seed: int = 0,
        backend: str = "xdt",
    ):
        self.sim = simulator if simulator is not None else Simulator(seed=seed)
        self.clock = VirtualClock(self.sim)
        # `backend` picks the default transfer medium; pass `transfer` to
        # bring your own engine (it should share this engine's clock, or
        # GB-second accounting runs on wall time while requests run virtual).
        self.transfer = (
            transfer if transfer is not None
            else TransferEngine(backend, clock=self.clock)
        )
        self.control = (
            control_plane if control_plane is not None
            else ControlPlane(clock=self.clock)
        )
        self.functions: Dict[str, Callable[[Context, Any], Any]] = {}
        self.service_times: Dict[str, float] = {}
        self.max_retries = max_retries
        self._invocation_ids = itertools.count(1)
        self._request_ids = itertools.count(1)
        self._executed_ids: set = set()
        self.records: List[InvocationRecord] = []
        self.requests: List[WorkflowRequest] = []

    # -- registration ----------------------------------------------------------
    def register(
        self,
        name: str,
        handler: Callable[[Context, Any], Any],
        policy: Optional[ScalingPolicy] = None,
        service_time: float = 0.0,
    ) -> None:
        """Register ``handler`` under ``name``.  ``service_time`` is the
        function's intrinsic compute duration in virtual seconds (on top of
        any ``ctx.sleep``/transfer debt it accrues)."""
        self.functions[name] = handler
        self.service_times[name] = service_time
        self.control.register(name, policy or ScalingPolicy(max_instances=16))

    # -- orchestrator ------------------------------------------------------------
    def submit(self, entry: str, payload: Any) -> WorkflowRequest:
        """Enqueue one workflow request; drive with ``drain()``/``run()``."""
        if entry not in self.functions:
            raise KeyError(f"unknown function {entry!r}")
        req = WorkflowRequest(
            request_id=next(self._request_ids),
            entry=entry,
            payload=payload,
            submitted_at=self.sim.now,
            done=self.sim.event(),
        )
        self.requests.append(req)
        self.sim.spawn(self._request_proc(req))
        return req

    def drain(self) -> List[WorkflowRequest]:
        """Run the simulator until every submitted request completed."""
        self.sim.run()
        pending = [r for r in self.requests if r.status in ("pending", "running")]
        if pending:
            raise RuntimeError(f"workflow deadlock: {pending}")
        return self.requests

    def run(self, entry: str, payload: Any) -> Any:
        """Blocking wrapper: submit one request and drive it to completion;
        on XDTProducerGone the orchestrator re-invokes the entry sub-workflow
        with the original arguments, up to ``max_retries`` times."""
        req = self.submit(entry, payload)
        self.sim.run()
        if req.status == "error":
            raise req.error
        return req.result

    def _request_proc(self, req: WorkflowRequest) -> Generator:
        req.status = "running"
        req.started_at = self.sim.now
        retries = 0
        while True:
            handle = self._spawn_invocation(req.entry, req.payload)
            req.attempts += 1
            yield handle.done
            if handle.error is None:
                req.status, req.result = "ok", handle.value
                break
            if isinstance(handle.error, XDTProducerGone) and retries < self.max_retries:
                # The producer instance is gone; its buffered objects died
                # with it.  Re-invoking from the entry function regenerates
                # them (paper §4.2.2) under fresh invocation ids.
                retries += 1
                continue
            req.status, req.error = "error", handle.error
            break
        req.finished_at = self.sim.now
        req.done.set(req)

    # -- execution ---------------------------------------------------------------
    def _next_invocation_id(self) -> int:
        invocation_id = next(self._invocation_ids)
        if invocation_id in self._executed_ids:  # pragma: no cover - invariant
            from .errors import InvocationReplayed

            raise InvocationReplayed(f"id {invocation_id} already executed")
        self._executed_ids.add(invocation_id)
        return invocation_id

    def _spawn_invocation(self, fn_name: str, payload: Any) -> AsyncResult:
        """Start one control-plane-mediated invocation as a sim process."""
        handle = AsyncResult(self.sim, fn_name)

        def proc():
            try:
                handle.value = yield from self._invocation_body(fn_name, payload)
            except BaseException as e:  # captured; surfaced at the waiter
                handle.error = e
            handle.done.set(handle)

        self.sim.spawn(proc())
        return handle

    def _invocation_body(self, fn_name: str, payload: Any) -> Generator:
        if fn_name not in self.functions:
            raise KeyError(f"unknown function {fn_name!r}")
        invocation_id = self._next_invocation_id()
        instance, wait = self.control.steer(fn_name)
        t0 = self.sim.now
        if wait > 0:                       # activator buffers across cold start
            yield self.sim.timeout(wait)
        ctrl = self.transfer.net.ctrl_plane_latency
        if ctrl > 0:
            yield self.sim.timeout(ctrl)
        ctx = Context(self, fn_name, attempt=0, instance=instance)
        status, code = "ok", None
        try:
            out = self.functions[fn_name](ctx, payload)
            if inspect.isgenerator(out):
                out = yield from self._drive(ctx, out)
            debt = ctx._take_debt() + self.service_times.get(fn_name, 0.0)
            if debt > 0:
                yield self.sim.timeout(debt)
            return out
        except XDTError as e:
            status, code = "error", e.code
            raise
        except BaseException:
            status = "error"               # foreign errors: no stable code
            raise
        finally:
            self.records.append(
                InvocationRecord(
                    invocation_id, fn_name, instance.instance_id, 0,
                    status, code, t_start=t0, t_end=self.sim.now,
                )
            )
            self.control.release(fn_name, instance.instance_id)

    def _drive(self, ctx: Context, gen: Generator) -> Generator:
        """Step a generator handler, paying debt at every yield boundary."""
        send, throw = None, None
        while True:
            try:
                yielded = gen.throw(throw) if throw is not None else gen.send(send)
            except StopIteration as stop:
                return stop.value
            send, throw = None, None
            debt = ctx._take_debt()
            if debt > 0:
                yield self.sim.timeout(debt)
            if isinstance(yielded, (int, float)):
                yield self.sim.timeout(float(yielded))
            elif isinstance(yielded, AsyncResult):
                yield yielded.done
                if yielded.error is not None:
                    throw = yielded.error
                else:
                    send = yielded.value
            elif isinstance(yielded, (list, tuple)) and all(
                isinstance(h, AsyncResult) for h in yielded
            ):
                yield self.sim.all_of([h.done for h in yielded])
                errs = [h.error for h in yielded if h.error is not None]
                if errs:
                    throw = errs[0]
                else:
                    send = [h.value for h in yielded]
            else:
                raise TypeError(
                    f"handler {ctx.function!r} yielded {type(yielded).__name__}; "
                    "yield seconds, an AsyncResult, or a list of AsyncResults"
                )

    def _invoke_inline(self, fn_name: str, payload: Any, parent: Context) -> Any:
        """Blocking sub-invocation from inside a running handler.

        Executes at the caller's current virtual instant; the callee's
        cold-start wait, control-plane hop, transfer debt, and service time
        are charged to the *caller's* debt (blocking-chain billing, the
        vSwarm semantics the cost model assumes).
        """
        if fn_name not in self.functions:
            raise KeyError(f"unknown function {fn_name!r}")
        invocation_id = self._next_invocation_id()
        instance, wait = self.control.steer(fn_name)
        t0 = self.sim.now
        parent._debt += wait + self.transfer.net.ctrl_plane_latency
        ctx = Context(self, fn_name, attempt=0, instance=instance)
        status, code = "ok", None
        try:
            out = self.functions[fn_name](ctx, payload)
            if inspect.isgenerator(out):
                raise TypeError(
                    f"generator handler {fn_name!r} cannot be invoked inline; "
                    "use ctx.call() / scatter_async() / submit()"
                )
            parent._debt += ctx._take_debt() + self.service_times.get(fn_name, 0.0)
            return out
        except XDTError as e:
            status, code = "error", e.code
            raise
        except BaseException:
            status = "error"               # foreign errors: no stable code
            raise
        finally:
            self.records.append(
                InvocationRecord(
                    invocation_id, fn_name, instance.instance_id, 0,
                    status, code, t_start=t0, t_end=self.sim.now,
                )
            )
            self.control.release(fn_name, instance.instance_id)

    # -- introspection -----------------------------------------------------------
    def executed_count(self, fn_name: Optional[str] = None) -> int:
        return sum(
            1 for r in self.records if fn_name is None or r.function == fn_name
        )

    def assert_at_most_once(self) -> None:
        """Invariant: no invocation id appears twice in the records."""
        ids = [r.invocation_id for r in self.records]
        assert len(ids) == len(set(ids)), "invocation id executed more than once"

    def latency_records(self) -> List[Tuple[int, float]]:
        """(request_id, end-to-end latency in virtual seconds) per request."""
        return [
            (r.request_id, r.latency_s)
            for r in self.requests
            if r.status in ("ok", "error")
        ]
