"""Event-driven workflow engine: concurrent function DAGs on virtual time.

A workflow is a DAG of named functions.  Each function is user logic with the
signature ``handler(ctx, payload) -> payload`` where ``ctx`` exposes the XDT
API (paper Table 1): ``ctx.invoke(fn, obj)``, ``ctx.put(obj, n) -> ref``,
``ctx.get(ref) -> obj``.  Placement is delegated to the control plane
(:mod:`repro.core.scheduler`), transfers to a :class:`TransferEngine`.

Execution model
---------------
The engine runs on the discrete-event :class:`~repro.core.cluster.Simulator`:
scheduler, transfer accounting, and per-request latency records all share one
:class:`~repro.core.clock.VirtualClock`.  Many workflow *requests* can be in
flight at once (``submit`` + ``drain``), their invocations overlap in virtual
time, and cold starts gate execution exactly as the autoscaler decides.

Two handler styles:

* **Plain handlers** (``def h(ctx, payload): return ...``) run atomically at
  one virtual instant; the virtual time they owe — cold-start waits, modeled
  transfer seconds from ``ctx.get`` (puts are producer-local buffering and
  charge nothing; the through-storage round-trip is billed at the pull),
  ``ctx.sleep`` compute, the function's registered ``service_time`` —
  accrues as *debt* that the engine pays as one timeout after the handler
  body.  ``ctx.invoke`` is a blocking inline sub-invocation, as before.
* **Generator handlers** (``def h(ctx, payload): ... yield ...``) interleave
  with the rest of the cluster at every yield.  Yield a number to spend
  compute seconds, an :class:`AsyncResult` from ``ctx.call(fn, obj)`` to
  await one concurrent sub-invocation, or a list of them for fan-out/fan-in
  that actually overlaps.

Semantics (paper §4.2.2), unchanged from the synchronous engine:

* **At-most-once per invocation id** — invocation ids are issued from a
  monotonic high-watermark counter, so an id at or below the watermark can
  never be executed (re-issued) again; :class:`InvocationReplayed` guards the
  invariant without keeping every id ever issued alive in a set.
* **Producer-death recovery** — if a consumer's ``get()`` raises
  ``XDTProducerGone``, the error propagates to the *orchestrator* (the
  request process), which re-invokes the entry sub-workflow with the same
  arguments under fresh invocation ids (at-least-once at workflow level,
  at-most-once per id).
* Retries are bounded (``max_retries``), after which the error surfaces to
  the caller — identical to Step Functions fallback behaviour.

The blocking ``run(entry, payload)`` API is a thin wrapper: one ``submit``
plus driving the simulator to quiescence.

Memory at sweep scale
---------------------
``WorkflowEngine(records="columnar")`` switches invocation and request
bookkeeping to parallel arrays (:class:`InvocationLog`, :class:`RequestLog`):
O(a few dozen bytes) per invocation instead of an object each, and completed
:class:`WorkflowRequest` shells are not retained — million-request sweeps fit
in memory.  The default (``records="objects"``) keeps the legacy object lists.
"""
from __future__ import annotations

import dataclasses
from array import array
from types import GeneratorType
from typing import Any, Callable, Dict, Generator, List, Optional, Sequence, Tuple

from .cluster import Event, Simulator
from .clock import VirtualClock
from .errors import InvocationReplayed, XDTError, XDTProducerGone
from .refs import XDTRef
from .scheduler import ControlPlane, ScalingPolicy
from .transfer import TransferEngine


@dataclasses.dataclass(slots=True)
class InvocationRecord:
    invocation_id: int
    function: str
    instance_id: int
    attempt: int
    status: str  # "ok" | "error"
    error_code: Optional[str] = None
    t_start: float = 0.0              # virtual time the invocation was steered
    t_end: float = 0.0                # virtual time it completed

    def overlaps(self, other: "InvocationRecord") -> bool:
        return self.t_start < other.t_end and other.t_start < self.t_end


class InvocationLog:
    """Columnar invocation records: parallel arrays, O(1) bookkeeping.

    Supports ``len``, indexing, and iteration (materializing
    :class:`InvocationRecord` views lazily) so introspection code written
    against the object list keeps working; the hot-path aggregates the
    engine and load generator need — count, billed seconds, per-function
    tallies — are maintained incrementally.
    """

    __slots__ = (
        "invocation_ids", "functions", "instance_ids", "statuses",
        "error_codes", "t_starts", "t_ends", "billed_s",
    )

    def __init__(self):
        self.invocation_ids = array("q")
        self.functions: List[str] = []
        self.instance_ids = array("q")
        self.statuses = array("b")        # 1 = ok, 0 = error
        self.error_codes: Dict[int, str] = {}   # sparse: index -> code
        self.t_starts = array("d")
        self.t_ends = array("d")
        self.billed_s = 0.0

    def append(
        self, invocation_id: int, function: str, instance_id: int,
        status: str, error_code: Optional[str], t_start: float, t_end: float,
    ) -> None:
        if error_code is not None:
            self.error_codes[len(self.invocation_ids)] = error_code
        self.invocation_ids.append(invocation_id)
        self.functions.append(function)
        self.instance_ids.append(instance_id)
        self.statuses.append(1 if status == "ok" else 0)
        self.t_starts.append(t_start)
        self.t_ends.append(t_end)
        self.billed_s += t_end - t_start

    def __len__(self) -> int:
        return len(self.invocation_ids)

    def __getitem__(self, i: int) -> InvocationRecord:
        if i < 0:
            i += len(self.invocation_ids)   # error_codes is keyed by position
        return InvocationRecord(
            invocation_id=self.invocation_ids[i],
            function=self.functions[i],
            instance_id=self.instance_ids[i],
            attempt=0,
            status="ok" if self.statuses[i] else "error",
            error_code=self.error_codes.get(i),
            t_start=self.t_starts[i],
            t_end=self.t_ends[i],
        )

    def __iter__(self):
        for i in range(len(self.invocation_ids)):
            yield self[i]


class RequestLog:
    """Columnar end-to-end request outcomes (columnar engine mode)."""

    __slots__ = ("request_ids", "latencies_s", "ok_flags")

    def __init__(self):
        self.request_ids = array("q")
        self.latencies_s = array("d")
        self.ok_flags = array("b")

    def append(self, request_id: int, latency_s: float, ok: bool) -> None:
        self.request_ids.append(request_id)
        self.latencies_s.append(latency_s)
        self.ok_flags.append(1 if ok else 0)

    def __len__(self) -> int:
        return len(self.request_ids)


@dataclasses.dataclass(slots=True)
class WorkflowRequest:
    """One end-to-end workflow execution tracked by the orchestrator."""

    request_id: int
    entry: str
    payload: Any
    submitted_at: float
    status: str = "pending"           # pending | running | ok | error
    result: Any = None
    error: Optional[BaseException] = None
    started_at: float = 0.0
    finished_at: float = 0.0
    attempts: int = 0
    done: Any = None                  # simulator Event, set on completion

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


class AsyncResult:
    """Handle for one concurrent sub-invocation (``ctx.call``)."""

    __slots__ = ("function", "done", "value", "error")

    def __init__(self, sim: Simulator, function: str):
        self.function = function
        self.done = Event(sim)
        self.value: Any = None
        self.error: Optional[BaseException] = None


class Context:
    """Per-invocation SDK handle given to user handlers."""

    __slots__ = ("_engine", "_debt", "function", "attempt", "instance")

    def __init__(
        self,
        engine: "WorkflowEngine",
        function: str,
        attempt: int,
        instance=None,
    ):
        self._engine = engine
        self._debt = 0.0              # virtual seconds owed at next pay point
        self.function = function
        self.attempt = attempt
        self.instance = instance

    # -- debt ------------------------------------------------------------
    def _take_debt(self) -> float:
        d, self._debt = self._debt, 0.0
        return d

    def sleep(self, seconds: float) -> None:
        """Spend ``seconds`` of virtual compute time in this invocation."""
        self._debt += max(0.0, float(seconds))

    # XDT API (paper Table 1)
    def invoke(self, fn_name: str, obj: Any) -> Any:
        """Blocking sub-invocation: the caller stalls until the callee is
        done, and inherits the callee's virtual-time debt."""
        return self._engine._invoke_inline(fn_name, obj, parent=self)

    def call(
        self, fn_name: str, obj: Any, affinity: Optional[Tuple[int, ...]] = None
    ) -> AsyncResult:
        """Concurrent sub-invocation.  Generator handlers ``yield`` the
        handle (or a list of handles) to fan-in.

        ``affinity`` is a placement hint forwarded to the callee's
        ``Deployment.steer``: pass this invocation's own coords
        (``ctx.instance.coords``) to ask the activator to land the callee on
        the caller's node when slots allow — the graph optimizer's
        co-placement pass rides this to make XDT pulls instance-local."""
        return self._engine._spawn_invocation(fn_name, obj, affinity=affinity)

    def put(
        self, obj: Any, n_retrievals: int = 1, backend: Optional[str] = None
    ) -> XDTRef:
        """Buffer ``obj``; ``backend`` overrides the engine's default medium
        for this one object (per-edge routing — the ref remembers its
        medium, so the consumer's ``get`` needs no extra argument)."""
        return self._engine.transfer.put(obj, n_retrievals, backend=backend)

    def get(self, ref: XDTRef, local: bool = False) -> Any:
        """One retrieval.  ``local=True`` marks this consumer as co-placed
        with the producer (scheduling honored an affinity hint): pulls of
        instance-resident media are modeled at shared-memory speed."""
        stats = self._engine.transfer.stats
        before = stats.modeled_seconds
        obj = self._engine.transfer.get(ref, local=local)
        # the modeled pull latency becomes virtual time owed by this function
        self._debt += stats.modeled_seconds - before
        return obj

    # collective conveniences built from the primitives (paper §7.1)
    def scatter(self, fn_name: str, objs: Sequence[Any]) -> List[Any]:
        return [self.invoke(fn_name, o) for o in objs]

    def scatter_async(self, fn_name: str, objs: Sequence[Any]) -> List[AsyncResult]:
        """Overlapping scatter: spawn all, fan-in with ``yield handles``."""
        return [self.call(fn_name, o) for o in objs]

    def broadcast(self, fn_name: str, obj: Any, fan: int) -> List[Any]:
        ref = self.put(obj, n_retrievals=fan)
        return [self.invoke(fn_name, ref) for _ in range(fan)]

    def gather(self, refs: Sequence[XDTRef]) -> List[Any]:
        return [self.get(r) for r in refs]


class WorkflowEngine:
    """Executes function DAGs concurrently with at-most-once semantics."""

    def __init__(
        self,
        transfer: Optional[TransferEngine] = None,
        control_plane: Optional[ControlPlane] = None,
        max_retries: int = 2,
        simulator: Optional[Simulator] = None,
        seed: int = 0,
        backend: str = "xdt",
        records: str = "objects",
    ):
        self.sim = simulator if simulator is not None else Simulator(seed=seed)
        self.clock = VirtualClock(self.sim)
        # `backend` picks the default transfer medium; pass `transfer` to
        # bring your own engine (it should share this engine's clock, or
        # GB-second accounting runs on wall time while requests run virtual).
        if transfer is not None:
            self.transfer = transfer
        else:
            # The registry's blocking flow control is wall-clock: on the
            # single-threaded virtual-time engine a blocked put() can never
            # be unblocked (the consumer that would free a slot runs on this
            # same thread), so the default 256-slot budget deadlocked sweeps
            # with a few hundred requests in flight.  Size the buffer budget
            # for sweep-scale concurrency instead; backpressure at this
            # layer is modeled in virtual time, not thread-blocked.
            from .buffers import BufferRegistry

            registry = BufferRegistry(
                max_slots=1 << 20, max_bytes=1 << 40, clock=self.clock
            )
            self.transfer = TransferEngine(
                backend, registry=registry, clock=self.clock
            )
        self.control = (
            control_plane if control_plane is not None
            else ControlPlane(clock=self.clock)
        )
        self.functions: Dict[str, Callable[[Context, Any], Any]] = {}
        self.service_times: Dict[str, float] = {}
        self._deployments: Dict[str, Any] = {}   # per-function direct dispatch
        self.max_retries = max_retries
        # high-watermark at-most-once: ids are issued monotonically; every id
        # <= the watermark is spent and can never be executed again
        self._invocation_watermark = 0
        self._request_counter = 0
        self._inflight_requests = 0
        if records not in ("objects", "columnar"):
            raise ValueError(f"records must be 'objects' or 'columnar', got {records!r}")
        self._columnar = records == "columnar"
        self.records: Any = InvocationLog() if self._columnar else []
        self.requests: List[WorkflowRequest] = []
        self.request_log = RequestLog() if self._columnar else None
        # prebound recorder: columnar appends go straight to the log with no
        # dispatch frame in between (the signatures match by construction)
        if self._columnar:
            self._record = self.records.append
        # net constants are frozen per engine: cache the control-plane hop
        self._ctrl_latency = self.transfer.net.ctrl_plane_latency

    # -- registration ----------------------------------------------------------
    def register(
        self,
        name: str,
        handler: Callable[[Context, Any], Any],
        policy: Optional[ScalingPolicy] = None,
        service_time: float = 0.0,
    ) -> None:
        """Register ``handler`` under ``name``.  ``service_time`` is the
        function's intrinsic compute duration in virtual seconds (on top of
        any ``ctx.sleep``/transfer debt it accrues)."""
        self.functions[name] = handler
        self.service_times[name] = service_time
        dep = self.control.register(
            name, policy or ScalingPolicy(max_instances=16)
        )
        # rate-driven autoscalers need requests-per-instance capacity before
        # the first completions exist; the registered service time is the
        # natural prior (no-op for telemetry-free legacy deployments)
        dep.seed_holding_estimate(service_time)
        self._deployments[name] = dep

    # -- orchestrator ------------------------------------------------------------
    def submit(self, entry: str, payload: Any) -> WorkflowRequest:
        """Enqueue one workflow request; drive with ``drain()``/``run()``."""
        if entry not in self.functions:
            raise KeyError(f"unknown function {entry!r}")
        self._request_counter += 1
        req = WorkflowRequest(
            request_id=self._request_counter,
            entry=entry,
            payload=payload,
            submitted_at=self.sim.now,
            done=Event(self.sim),
        )
        self._inflight_requests += 1
        if not self._columnar:
            # columnar mode does not retain completed request shells; the
            # outcome lands in `request_log` instead
            self.requests.append(req)
        self.sim.spawn(self._request_proc(req))
        return req

    def drain(self) -> List[WorkflowRequest]:
        """Run the simulator until every submitted request completed."""
        self.sim.run()
        if self._inflight_requests:
            pending = [
                r for r in self.requests if r.status in ("pending", "running")
            ] or self._inflight_requests
            raise RuntimeError(f"workflow deadlock: {pending}")
        return self.requests

    def run(self, entry: str, payload: Any) -> Any:
        """Blocking wrapper: submit one request and drive it to completion;
        on XDTProducerGone the orchestrator re-invokes the entry sub-workflow
        with the original arguments, up to ``max_retries`` times."""
        req = self.submit(entry, payload)
        self.sim.run()
        if req.status == "error":
            raise req.error
        return req.result

    def _request_proc(self, req: WorkflowRequest) -> Generator:
        req.status = "running"
        req.started_at = self.sim.now
        retries = 0
        while True:
            handle = self._spawn_invocation(req.entry, req.payload)
            req.attempts += 1
            yield handle.done
            if handle.error is None:
                req.status, req.result = "ok", handle.value
                break
            if isinstance(handle.error, XDTProducerGone) and retries < self.max_retries:
                # The producer instance is gone; its buffered objects died
                # with it.  Re-invoking from the entry function regenerates
                # them (paper §4.2.2) under fresh invocation ids.
                retries += 1
                continue
            req.status, req.error = "error", handle.error
            break
        req.finished_at = self.sim.now
        self._inflight_requests -= 1
        if self._columnar:
            self.request_log.append(
                req.request_id, req.finished_at - req.submitted_at,
                req.status == "ok",
            )
        req.done.set(req)

    # -- execution ---------------------------------------------------------------
    def _next_invocation_id(self) -> int:
        invocation_id = self._invocation_watermark + 1
        if invocation_id <= self._invocation_watermark:  # pragma: no cover
            raise InvocationReplayed(f"id {invocation_id} already executed")
        self._invocation_watermark = invocation_id
        return invocation_id

    def _record(
        self, invocation_id: int, fn_name: str, instance_id: int,
        status: str, code: Optional[str], t_start: float, t_end: float,
    ) -> None:
        # objects mode only; columnar engines bind InvocationLog.append
        # directly over this method in __init__
        self.records.append(
            InvocationRecord(
                invocation_id, fn_name, instance_id, 0,
                status, code, t_start=t_start, t_end=t_end,
            )
        )

    def _spawn_invocation(
        self,
        fn_name: str,
        payload: Any,
        affinity: Optional[Tuple[int, ...]] = None,
    ) -> AsyncResult:
        """Start one control-plane-mediated invocation as a sim process."""
        handle = AsyncResult(self.sim, fn_name)
        self.sim.spawn(self._invocation_proc(handle, fn_name, payload, affinity))
        return handle

    def _invocation_proc(
        self,
        handle: AsyncResult,
        fn_name: str,
        payload: Any,
        affinity: Optional[Tuple[int, ...]] = None,
    ) -> Generator:
        """One control-plane-mediated invocation: steer, pay the cold-start
        and control-plane timeouts, run the handler, pay its debt, record.
        (Single generator frame per invocation — this is the hot path.)"""
        try:
            fn = self.functions.get(fn_name)
            if fn is None:
                raise KeyError(f"unknown function {fn_name!r}")
            invocation_id = self._next_invocation_id()
            deployment = self._deployments[fn_name]
            instance, wait = deployment.steer(affinity)
            sim = self.sim
            t0 = sim.now
            # separate timeouts for the activator's cold-start buffering and
            # the control-plane hop: merging them would re-associate the
            # float sums and shift timestamps by ulps vs the legacy engine
            if wait > 0:                   # activator buffers across cold start
                yield wait
            ctrl = self._ctrl_latency
            if ctrl > 0:
                yield ctrl
            ctx = Context(self, fn_name, attempt=0, instance=instance)
            status, code = "ok", None
            try:
                out = fn(ctx, payload)
                if type(out) is GeneratorType:
                    out = yield from self._drive(ctx, out)
                debt = ctx._take_debt() + self.service_times[fn_name]
                if debt > 0:
                    yield debt
                handle.value = out
            except XDTError as e:
                status, code = "error", e.code
                raise
            except BaseException:
                status = "error"           # foreign errors: no stable code
                raise
            finally:
                self._record(
                    invocation_id, fn_name, instance.instance_id,
                    status, code, t0, sim.now,
                )
                deployment.release(instance.instance_id)
        except BaseException as e:  # captured; surfaced at the waiter
            handle.error = e
        handle.done.set(handle)

    def _drive(self, ctx: Context, gen: Generator) -> Generator:
        """Step a generator handler, paying debt at every yield boundary."""
        send, throw = None, None
        while True:
            try:
                yielded = gen.throw(throw) if throw is not None else gen.send(send)
            except StopIteration as stop:
                return stop.value
            send, throw = None, None
            debt = ctx._take_debt()
            if debt > 0:
                yield debt
            if isinstance(yielded, (int, float)):
                yield float(yielded)
            elif isinstance(yielded, AsyncResult):
                yield yielded.done
                if yielded.error is not None:
                    throw = yielded.error
                else:
                    send = yielded.value
            elif isinstance(yielded, (list, tuple)) and all(
                isinstance(h, AsyncResult) for h in yielded
            ):
                yield self.sim.all_of([h.done for h in yielded])
                errs = [h.error for h in yielded if h.error is not None]
                if errs:
                    throw = errs[0]
                else:
                    send = [h.value for h in yielded]
            elif isinstance(yielded, Event):
                # raw simulator event: lets handlers wait on external
                # completion signals (e.g. the disaggregated server bridging
                # real decode completion into virtual time)
                yield yielded
                send = yielded.value
            else:
                raise TypeError(
                    f"handler {ctx.function!r} yielded {type(yielded).__name__}; "
                    "yield seconds, an AsyncResult, a list of AsyncResults, "
                    "or a simulator Event"
                )

    def _invoke_inline(self, fn_name: str, payload: Any, parent: Context) -> Any:
        """Blocking sub-invocation from inside a running handler.

        Executes at the caller's current virtual instant; the callee's
        cold-start wait, control-plane hop, transfer debt, and service time
        are charged to the *caller's* debt (blocking-chain billing, the
        vSwarm semantics the cost model assumes).
        """
        fn = self.functions.get(fn_name)
        if fn is None:
            raise KeyError(f"unknown function {fn_name!r}")
        invocation_id = self._next_invocation_id()
        deployment = self._deployments[fn_name]
        instance, wait = deployment.steer()
        t0 = self.sim.now
        parent._debt += wait + self._ctrl_latency
        ctx = Context(self, fn_name, attempt=0, instance=instance)
        status, code = "ok", None
        try:
            out = fn(ctx, payload)
            if type(out) is GeneratorType:
                raise TypeError(
                    f"generator handler {fn_name!r} cannot be invoked inline; "
                    "use ctx.call() / scatter_async() / submit()"
                )
            parent._debt += ctx._take_debt() + self.service_times[fn_name]
            return out
        except XDTError as e:
            status, code = "error", e.code
            raise
        except BaseException:
            status = "error"               # foreign errors: no stable code
            raise
        finally:
            self._record(
                invocation_id, fn_name, instance.instance_id,
                status, code, t0, self.sim.now,
            )
            deployment.release(instance.instance_id)

    # -- introspection -----------------------------------------------------------
    def executed_count(self, fn_name: Optional[str] = None) -> int:
        if self._columnar:
            if fn_name is None:
                return len(self.records)
            return self.records.functions.count(fn_name)
        return sum(
            1 for r in self.records if fn_name is None or r.function == fn_name
        )

    def billed_virtual_seconds(self) -> float:
        """Sum of per-invocation (t_end - t_start) across all records."""
        if self._columnar:
            return self.records.billed_s
        return sum(r.t_end - r.t_start for r in self.records)

    def assert_at_most_once(self) -> None:
        """Invariant: no invocation id appears twice in the records."""
        if self._columnar:
            ids = list(self.records.invocation_ids)
        else:
            ids = [r.invocation_id for r in self.records]
        assert len(ids) == len(set(ids)), "invocation id executed more than once"

    def latency_records(self) -> List[Tuple[int, float]]:
        """(request_id, end-to-end latency in virtual seconds) per request."""
        if self._columnar:
            log = self.request_log
            # the log appends in completion order; report in request-id
            # (submission) order like the legacy object list
            return sorted(zip(log.request_ids, log.latencies_s))
        return [
            (r.request_id, r.latency_s)
            for r in self.requests
            if r.status in ("ok", "error")
        ]
