"""Edge-cloud continuum topology: node -> zone -> region (-> edge-site).

The paper's cluster is flat — every node sees the same NIC, RTT, and price
sheet.  Truffle (arxiv 2411.16451) extends the same data-movement problem
across an edge->cloud hierarchy where *crossing a tier boundary* changes both
latency and the bill.  This module is the dependency-light model layer:

* :class:`Zone` — a named zone inside a region, on a site (``"cloud"`` or
  ``"edge"``).  Every simulated node lives in exactly one zone.
* :class:`Topology` — an ordered set of zones plus per-stage pins.  It
  precomputes the *crossing level* between any two zones: the lowest common
  tier of producer and consumer, which prices and paces every pull.
* :class:`Coord` — a typed placement coordinate.  It subclasses ``tuple`` so
  it hashes/compares exactly like the ad-hoc tuples the scheduler has always
  used (``_coords_index`` keys, ``ctx.instance.coords`` equality, plan
  ``colocal`` maps all keep working bit-for-bit), while *also* carrying the
  tier path (zone / region / site) for zone-affine steering.

Crossing levels (monotone: each step is slower and pricier than the last)::

    0  SAME_NODE     shared-memory pull, never leaves the host
    1  SAME_ZONE     datacenter NIC fabric (today's flat cluster)
    2  CROSS_ZONE    inter-AZ link inside one region
    3  CROSS_REGION  WAN between regions (or between two edge sites)
    4  CROSS_SITE    edge <-> cloud uplink

The degenerate single-zone :class:`Topology` maps every node to the same
zone, so every crossing collapses to level <= 1 and both lowerings take
exactly the flat-cluster code path — sha goldens and BENCH_engine checksums
are bit-identical by construction (pinned by ``tests/test_topology.py``).

This module must stay import-light (no cluster/scheduler/dag imports): both
lowerings and the optimizer import *it*.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple, Union

__all__ = [
    "SAME_NODE",
    "SAME_ZONE",
    "CROSS_ZONE",
    "CROSS_REGION",
    "CROSS_SITE",
    "TIER_NAMES",
    "Coord",
    "as_coord",
    "Zone",
    "Topology",
    "FLAT_TOPOLOGY",
]

SAME_NODE = 0
SAME_ZONE = 1
CROSS_ZONE = 2
CROSS_REGION = 3
CROSS_SITE = 4

TIER_NAMES = ("same-node", "same-zone", "cross-zone", "cross-region", "cross-site")


class Coord(tuple):
    """Typed placement coordinate: the scheduler's opaque coords tuple plus
    an optional tier path.

    ``Coord((3,))`` equals and hashes like the plain ``(3,)`` the default
    placer produces, so it can be handed to every surface that accepts
    coords today — ``Deployment.steer(prefer=)``, ``ctx.call(affinity=)``,
    ``ControlPlane.kill_node`` — and old tuple inputs keep working (they are
    coerced through :func:`as_coord` at the public surfaces).
    """

    # tuple subclasses cannot carry non-empty __slots__; zone/region/site
    # live in the instance dict and default to None for path-less coords.

    def __new__(
        cls,
        body: Iterable = (),
        zone: Optional[str] = None,
        region: Optional[str] = None,
        site: Optional[str] = None,
    ) -> "Coord":
        self = super().__new__(cls, tuple(body))
        self.zone = zone
        self.region = region
        self.site = site
        return self

    @property
    def path(self) -> Tuple[Optional[str], Optional[str], Optional[str]]:
        """(site, region, zone) — coarse to fine."""
        return (self.site, self.region, self.zone)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        base = tuple.__repr__(self)
        if self.zone is None and self.region is None and self.site is None:
            return f"Coord{base}"
        return f"Coord{base}@{self.site}/{self.region}/{self.zone}"

    # Equality/hash are inherited from tuple ON PURPOSE: a Coord and a plain
    # tuple with the same body are the same key everywhere coords are used.


def as_coord(value) -> Optional[Coord]:
    """Coercion shim: accept legacy tuples (and lists) wherever a
    :class:`Coord` flows today.  ``None`` passes through; an existing
    :class:`Coord` is returned unchanged (tier path preserved)."""
    if value is None or isinstance(value, Coord):
        return value
    if isinstance(value, (tuple, list)):
        return Coord(value)
    raise TypeError(f"cannot interpret {value!r} as placement coords")


@dataclasses.dataclass(frozen=True)
class Zone:
    """One zone of the continuum: ``name`` within ``region`` on ``site``."""

    name: str
    region: str = "local"
    site: str = "cloud"


PinSpec = Union[str, Sequence[str]]


class Topology:
    """Ordered zones + per-stage pins, with precomputed crossing levels.

    Parameters
    ----------
    zones:
        The zones, in order.  Zone order matters twice: node -> zone
        assignment is deterministic in it, and the *naive* (topology-
        oblivious) stage spread round-robins over it.
    pin:
        Hard placement constraints: stage name -> zone name, or a sequence
        of zone names to spread that stage's instances across (instance
        ``i`` lands in ``pins[i % len(pins)]``).  Pins model workload
        semantics (sensors live at the edge, trainers need cloud
        accelerators) and are honored by naive and optimized placement
        alike.
    """

    def __init__(
        self,
        zones: Sequence[Zone] = (Zone("z0"),),
        pin: Optional[Mapping[str, PinSpec]] = None,
    ) -> None:
        if not zones:
            raise ValueError("Topology needs at least one zone")
        self.zones: Tuple[Zone, ...] = tuple(zones)
        names = [z.name for z in self.zones]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate zone names: {names}")
        self.zone_index: Dict[str, int] = {n: i for i, n in enumerate(names)}
        self.pin: Dict[str, Tuple[str, ...]] = {}
        for stage, spec in dict(pin or {}).items():
            zs = (spec,) if isinstance(spec, str) else tuple(spec)
            for z in zs:
                if z not in self.zone_index:
                    raise ValueError(f"pin for {stage!r} names unknown zone {z!r}")
            self.pin[stage] = zs
        n = len(self.zones)
        self._crossing = [[self._level(i, j) for j in range(n)] for i in range(n)]

    def _level(self, i: int, j: int) -> int:
        if i == j:
            return SAME_ZONE
        a, b = self.zones[i], self.zones[j]
        if a.site != b.site:
            return CROSS_SITE
        if a.region != b.region:
            return CROSS_REGION
        return CROSS_ZONE

    # -- queries ----------------------------------------------------------
    @property
    def is_flat(self) -> bool:
        """Single zone: indistinguishable from today's flat cluster."""
        return len(self.zones) == 1

    def crossing(self, zi: int, zj: int) -> int:
        """Crossing level between two zones (>= SAME_ZONE; the same-node
        level is the caller's to detect — zones cannot see node identity)."""
        return self._crossing[zi][zj]

    @property
    def service_zone(self) -> int:
        """Where storage services (S3 / ElastiCache front-ends) are homed:
        the first cloud-site zone, or zone 0 if the topology is edge-only."""
        for i, z in enumerate(self.zones):
            if z.site == "cloud":
                return i
        return 0

    def coord(self, body: Iterable, zi: int) -> Coord:
        """A :class:`Coord` carrying zone ``zi``'s full tier path."""
        z = self.zones[zi]
        return Coord(body, zone=z.name, region=z.region, site=z.site)

    # -- stage -> zone assignment ----------------------------------------
    def assign_stage_zones(
        self,
        stage_names: Sequence[str],
        plan_zones: Optional[Mapping[str, PinSpec]] = None,
    ) -> Dict[str, Tuple[int, ...]]:
        """Per-stage zone assignment (instance ``i`` of a stage lands in
        ``zs[i % len(zs)]``).

        Precedence: workload pins (hard constraints) > optimizer plan zones
        > the *naive spread* — a topology-oblivious scheduler that round-
        robins unpinned stages across all zones in declaration order.  The
        naive spread is the fig14 "flat placement" baseline; with a single
        zone it degenerates to "everything in zone 0", i.e. today's
        cluster.
        """
        plan_zones = dict(plan_zones or {})
        out: Dict[str, Tuple[int, ...]] = {}
        k = 0
        for name in stage_names:
            if name in self.pin:
                out[name] = tuple(self.zone_index[z] for z in self.pin[name])
            elif name in plan_zones:
                spec = plan_zones[name]
                zs = (spec,) if isinstance(spec, str) else tuple(spec)
                out[name] = tuple(self.zone_index[z] for z in zs)
            else:
                out[name] = (k % len(self.zones),)
                k += 1
        return out

    def describe(self) -> Dict[str, object]:
        return {
            "zones": [dataclasses.asdict(z) for z in self.zones],
            "pin": {s: list(zs) for s, zs in self.pin.items()},
            "service_zone": self.zones[self.service_zone].name,
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Topology({[z.name for z in self.zones]!r}, pin={self.pin!r})"


#: The degenerate topology: one cloud zone, no pins — today's flat cluster.
FLAT_TOPOLOGY = Topology()
