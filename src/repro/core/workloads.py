"""The paper's three real-world workloads (§6.5) on the calibrated simulator.

Each workload is a blocking-invocation DAG (vSwarm-style: "a caller function
waits for the callee to respond"), so a function's *billed* duration spans
its whole subtree — which is why slow transfers inflate the compute bill too
(paper §6.5.1) and why Table 2's compute column differs per backend.

Workload structure and the communication patterns they exercise:

* **VID** (Video Analytics): streaming --fragment--> decoder --scatter
  frames--> N recognizers.  1-1 + scatter.
* **SET** (Stacking Ensemble Training): driver broadcasts the training set
  (many small chunks — the S3-hostile access pattern) to K trainers, gathers
  models + fold predictions.  broadcast + gather.
* **MR** (MapReduce, AMPLab aggregation query): M mappers read input from S3
  (never optimized — original data), shuffle M x R ephemeral slices through
  the backend, R reducers aggregate.  The shuffle IS the gather pattern at
  scale, and the reason MR's ephemeral bill dominates (Table 2: EC costs
  772x XDT here).

Parameters are calibrated so the per-backend speedups and cost ratios land
on the paper's Fig. 7 / Table 2 anchors (see tests/test_workloads.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Generator, List, Tuple

from .cluster import DEFAULT_NET, NetConstants, ServerlessCluster
from .cost import CostBreakdown, WorkflowCostInputs, workflow_cost

BACKENDS = ("s3", "elasticache", "xdt")


@dataclasses.dataclass
class WorkloadResult:
    backend: str
    latency_s: float
    breakdown: Dict[str, float]          # phase -> seconds (critical path)
    cost: CostBreakdown
    inputs: WorkflowCostInputs


class _Billing:
    """Tracks per-invocation billed spans (blocking-chain semantics)."""

    def __init__(self, sim):
        self.sim = sim
        self.spans: List[Tuple[str, float, float]] = []
        self._open: Dict[int, Tuple[str, float]] = {}
        self._next = 0

    def start(self, name: str) -> int:
        self._next += 1
        self._open[self._next] = (name, self.sim.now)
        return self._next

    def stop(self, token: int) -> None:
        name, t0 = self._open.pop(token)
        self.spans.append((name, t0, self.sim.now))

    @property
    def n_invocations(self) -> int:
        return len(self.spans) + len(self._open)

    @property
    def billed_s(self) -> float:
        return sum(t1 - t0 for _, t0, t1 in self.spans)


def _mk(backend: str, n_nodes: int, net, seed, deterministic):
    cluster = ServerlessCluster(n_nodes, net, seed=seed, deterministic=deterministic)
    return cluster, cluster.sim, _Billing(cluster.sim)


def _put_get(cluster, backend, src, dst, nbytes) -> Generator:
    """One ephemeral object src -> dst through the chosen backend."""
    if backend in ("s3", "elasticache"):
        yield cluster.storage_put(backend, src, nbytes)
        yield cluster.invoke_ctrl()
        yield cluster.storage_get(backend, dst, nbytes)
    else:  # xdt: invoke carries the ref, consumer pulls
        yield cluster.invoke_ctrl()
        yield cluster.xdt_pull(src, nbytes)


def _chunked_get(cluster, backend, src, dst, n_chunks, chunk_bytes, concurrency):
    """Fetch ``n_chunks`` small objects with bounded client concurrency —
    the op-latency-bound access pattern of chunked datasets (SET)."""
    per_wave = max(1, concurrency)
    waves = (n_chunks + per_wave - 1) // per_wave

    def one_wave(k):
        evs = []
        for _ in range(min(per_wave, n_chunks - k * per_wave)):
            if backend in ("s3", "elasticache"):
                evs.append(cluster.storage_get(backend, dst, chunk_bytes))
            else:
                evs.append(cluster.xdt_pull(src, chunk_bytes))
        return cluster.sim.all_of(evs)

    for k in range(waves):
        yield one_wave(k)


def _seq_puts(cluster, backend, src, n, nbytes):
    """n sequential storage puts (sync SDK loop, the vSwarm access pattern)."""
    for _ in range(n):
        yield cluster.storage_put(backend, src, nbytes)


def _seq_gets(cluster, backend, dst, n, nbytes):
    for _ in range(n):
        yield cluster.storage_get(backend, dst, nbytes)


def _seq_pulls(cluster, producers, nbytes):
    for p in producers:
        yield cluster.xdt_pull(p, nbytes)


# ---------------------------------------------------------------------------
# VID — Video Analytics: streaming -> decoder -> scatter(recognizers)
# ---------------------------------------------------------------------------

VID_FRAGMENT = 30 << 20          # video fragment, streaming -> decoder
VID_FRAME_BATCH = 3 << 20        # decoded frames, decoder -> each recognizer
VID_FAN = 4
VID_COMPUTE = {"streaming": 0.05, "decoder": 0.35, "recognition": 0.40}


def run_vid(backend: str, net: NetConstants = DEFAULT_NET, seed: int = 0,
            deterministic: bool = False) -> WorkloadResult:
    # nodes: 0 streaming, 1 decoder, 2.. recognizers
    cluster, sim, bill = _mk(backend, 2 + VID_FAN, net, seed, deterministic)
    marks: Dict[str, float] = {}

    def recognition(i):
        tok = bill.start("recognition")
        yield from _put_get(cluster, backend, 1, 2 + i, VID_FRAME_BATCH)
        marks.setdefault("frames_done", sim.now)
        marks["frames_done"] = max(marks["frames_done"], sim.now)
        yield sim.timeout(VID_COMPUTE["recognition"])
        bill.stop(tok)

    def decoder():
        tok = bill.start("decoder")
        yield from _put_get(cluster, backend, 0, 1, VID_FRAGMENT)
        marks["fragment_done"] = sim.now
        yield sim.timeout(VID_COMPUTE["decoder"])
        marks["decode_done"] = sim.now
        procs = [sim.spawn(recognition(i)).done for i in range(VID_FAN)]
        yield sim.all_of(procs)          # blocking scatter
        bill.stop(tok)

    def streaming():
        tok = bill.start("streaming")
        yield sim.timeout(VID_COMPUTE["streaming"])
        yield sim.spawn(decoder()).done  # blocking call
        bill.stop(tok)

    root = sim.spawn(streaming())
    sim.run()
    assert root.done.fired
    breakdown = {
        "streaming_compute": VID_COMPUTE["streaming"],
        "fragment_transfer": marks["fragment_done"] - VID_COMPUTE["streaming"],
        "decode_compute": VID_COMPUTE["decoder"],
        "frames_transfer": marks["frames_done"] - marks["decode_done"],
        "recognition_compute": sim.now - marks["frames_done"],
    }
    return _result(backend, cluster, sim, bill, breakdown)


# ---------------------------------------------------------------------------
# SET — Stacking Ensemble Training: broadcast(chunked dataset) -> gather
# ---------------------------------------------------------------------------

SET_K = 8                         # trainers
SET_CHUNKS = 8                    # dataset objects (chunked, sync-SDK gets)
SET_CHUNK_BYTES = 8 << 20         # 8 MB -> 64 MB dataset
SET_MODEL_BYTES = 1 << 20         # trained model + fold predictions
SET_CONCURRENCY = 1               # sync SDK: sequential gets per trainer
SET_COMPUTE = {"driver": 0.05, "trainer": 0.10, "reconcile": 0.10}


def run_set(backend: str, net: NetConstants = DEFAULT_NET, seed: int = 0,
            deterministic: bool = False) -> WorkloadResult:
    # nodes: 0 driver, 1.. trainers
    cluster, sim, bill = _mk(backend, 1 + SET_K, net, seed, deterministic)
    marks: Dict[str, float] = {"bcast_done": 0.0, "gather_start": 0.0}

    def trainer(i):
        tok = bill.start("trainer")
        # broadcast leg: pull the chunked dataset (same objects for all)
        yield from _chunked_get(
            cluster, backend, 0, 1 + i, SET_CHUNKS, SET_CHUNK_BYTES,
            SET_CONCURRENCY,
        )
        marks["bcast_done"] = max(marks["bcast_done"], sim.now)
        yield sim.timeout(SET_COMPUTE["trainer"])
        # gather leg: publish model + fold predictions
        if backend in ("s3", "elasticache"):
            yield cluster.storage_put(backend, 1 + i, SET_MODEL_BYTES)
        bill.stop(tok)

    def driver():
        # Orchestrated (Step-Functions-style) workflow: the driver bills its
        # own compute + transfers, NOT the children's training time.
        tok = bill.start("driver")
        yield sim.timeout(SET_COMPUTE["driver"])
        if backend in ("s3", "elasticache"):
            # dataset staged into the service once (chunk by chunk)
            yield from _seq_puts(cluster, backend, 0, SET_CHUNKS, SET_CHUNK_BYTES)
        bill.stop(tok)
        done = [sim.spawn(trainer(i)).done for i in range(SET_K)]
        yield sim.all_of(done)           # orchestrator wait (not billed)
        tok = bill.start("driver_gather")
        marks["gather_start"] = sim.now
        # gather the K models/predictions
        if backend in ("s3", "elasticache"):
            evs = [cluster.storage_get(backend, 0, SET_MODEL_BYTES) for _ in range(SET_K)]
        else:
            evs = [cluster.xdt_pull(1 + i, SET_MODEL_BYTES) for i in range(SET_K)]
        yield sim.all_of(evs)
        marks["gather_done"] = sim.now
        yield sim.timeout(SET_COMPUTE["reconcile"])
        bill.stop(tok)

    root = sim.spawn(driver())
    sim.run()
    assert root.done.fired
    breakdown = {
        "driver_compute": SET_COMPUTE["driver"],
        "broadcast_dataset": marks["bcast_done"] - SET_COMPUTE["driver"],
        "train_compute": marks["gather_start"] - marks["bcast_done"],
        "gather_models": marks["gather_done"] - marks["gather_start"],
        "reconcile_compute": SET_COMPUTE["reconcile"],
    }
    return _result(backend, cluster, sim, bill, breakdown)


# ---------------------------------------------------------------------------
# MR — MapReduce aggregation query: S3 input -> shuffle(backend) -> reduce
# ---------------------------------------------------------------------------

MR_M = 8                          # mappers
MR_R = 8                          # reducers
MR_INPUT_BYTES = 240 << 20        # per-mapper input (always via S3)
MR_SLICE_BYTES = 8 << 20          # per (mapper, reducer) shuffle slice
MR_COMPUTE = {"driver": 0.02, "mapper": 0.55, "reducer": 0.55}


def run_mr(backend: str, net: NetConstants = DEFAULT_NET, seed: int = 0,
           deterministic: bool = False) -> WorkloadResult:
    # nodes: 0 driver, 1..M mappers, M+1..M+R reducers
    cluster, sim, bill = _mk(backend, 1 + MR_M + MR_R, net, seed, deterministic)
    marks: Dict[str, float] = {"input_done": 0.0, "map_done": 0.0,
                               "shuffle_get_done": 0.0}

    def mapper(i):
        tok = bill.start("mapper")
        node = 1 + i
        # original input ALWAYS comes from S3 (paper: not optimized by XDT)
        yield cluster.storage_get("s3", node, MR_INPUT_BYTES)
        marks["input_done"] = max(marks["input_done"], sim.now)
        yield sim.timeout(MR_COMPUTE["mapper"])
        # shuffle put: R slices for the reducers (sync SDK: sequential)
        if backend in ("s3", "elasticache"):
            yield from _seq_puts(cluster, backend, node, MR_R, MR_SLICE_BYTES)
        marks["map_done"] = max(marks["map_done"], sim.now)
        bill.stop(tok)

    def reducer(j):
        tok = bill.start("reducer")
        node = 1 + MR_M + j
        # shuffle get: one slice from every mapper (sync SDK: sequential)
        if backend in ("s3", "elasticache"):
            yield from _seq_gets(cluster, backend, node, MR_M, MR_SLICE_BYTES)
        else:
            yield from _seq_pulls(cluster, [1 + i for i in range(MR_M)],
                                  MR_SLICE_BYTES)
        marks["shuffle_get_done"] = max(marks["shuffle_get_done"], sim.now)
        yield sim.timeout(MR_COMPUTE["reducer"])
        bill.stop(tok)      # aggregation output is tiny -> inline response

    def driver():
        # orchestrated workflow: the driver's wait on children is not billed
        tok = bill.start("driver")
        yield sim.timeout(MR_COMPUTE["driver"])
        bill.stop(tok)
        yield sim.all_of([sim.spawn(mapper(i)).done for i in range(MR_M)])
        yield sim.all_of([sim.spawn(reducer(j)).done for j in range(MR_R)])

    root = sim.spawn(driver())
    sim.run()
    assert root.done.fired
    breakdown = {
        "input_read_s3": marks["input_done"] - MR_COMPUTE["driver"],
        "map_compute": MR_COMPUTE["mapper"],
        "mapper_put": marks["map_done"] - marks["input_done"] - MR_COMPUTE["mapper"],
        "reducer_get": marks["shuffle_get_done"] - marks["map_done"],
        "reduce_compute": sim.now - marks["shuffle_get_done"],
    }
    return _result(backend, cluster, sim, bill, breakdown)


# ---------------------------------------------------------------------------
# shared tail: cost assembly
# ---------------------------------------------------------------------------


def _result(backend, cluster, sim, bill, breakdown) -> WorkloadResult:
    acct = cluster.accounting(backend if backend != "xdt" else "s3")
    # MR reads input via S3 regardless of the ephemeral backend; merge both
    # accountings so the S3 request fees always appear.
    s3_acct = cluster.accounting("s3")
    eph_acct = cluster.accounting(backend) if backend != "s3" else s3_acct
    eph_acct.touch(sim.now)
    inputs = WorkflowCostInputs(
        n_function_invocations=bill.n_invocations,
        billed_duration_s=bill.billed_s,
        n_storage_puts=eph_acct.n_storage_puts,
        n_storage_gets=eph_acct.n_storage_gets,
        storage_gb_seconds=eph_acct.storage_gb_seconds,
        peak_resident_gb=eph_acct.peak_resident_gb,
    )
    cost = workflow_cost(inputs, backend)
    if backend != "s3" and s3_acct is not eph_acct and (
        s3_acct.n_storage_puts or s3_acct.n_storage_gets
    ):
        # add the non-optimizable S3 input/output fees on top
        from .cost import s3_storage_cost

        s3_acct.touch(sim.now)
        extra = s3_storage_cost(
            s3_acct.n_storage_puts, s3_acct.n_storage_gets,
            s3_acct.storage_gb_seconds,
        )
        cost = CostBreakdown(cost.compute, cost.storage + extra)
    return WorkloadResult(
        backend=backend,
        latency_s=sim.now,
        breakdown=breakdown,
        cost=cost,
        inputs=inputs,
    )


WORKLOADS = {"vid": run_vid, "set": run_set, "mr": run_mr}


def run_all(deterministic: bool = True, seed: int = 0):
    """{workload: {backend: WorkloadResult}} across the full matrix."""
    return {
        name: {b: fn(b, seed=seed, deterministic=deterministic) for b in BACKENDS}
        for name, fn in WORKLOADS.items()
    }
