"""The paper's three real-world workloads (§6.5), declared as WorkflowDAGs.

Each workload is a :class:`~repro.core.dag.WorkflowDAG` — stages with
compute times, edges with per-object sizes and transfer policies — executed
on the calibrated simulator via ``dag.compile(target="cluster")``.
For a fixed single backend the DAG interpreter reproduces the legacy
hand-rolled generators bit-for-bit (guarded differentially in
``tests/test_dag.py``); the ``"hybrid"`` backend routes every ``"default"``
edge through :data:`HYBRID_ROUTE` (inline under the activator payload cap,
XDT otherwise, S3 for evictable producers), and the run is priced per
medium via :func:`repro.core.cost.routed_workflow_cost`.

Billing is vSwarm-style where declared blocking ("a caller function waits
for the callee to respond"), so a function's *billed* duration spans its
whole subtree — which is why slow transfers inflate the compute bill too
(paper §6.5.1) and why Table 2's compute column differs per backend.

Workload structure and the communication patterns they exercise:

* **VID** (Video Analytics): streaming --fragment--> decoder --scatter
  frames--> N recognizers.  1-1 + scatter, blocking chain.
* **SET** (Stacking Ensemble Training): driver broadcasts the training set
  (many small chunks — the S3-hostile access pattern) to K trainers, gathers
  models + fold predictions.  broadcast + gather, orchestrated.
* **MR** (MapReduce, AMPLab aggregation query): M mappers read input from S3
  (never optimized — original data; the ``input`` edge is pinned
  ``route="s3"``), shuffle M x R ephemeral slices through the backend, R
  reducers aggregate.  The shuffle IS the gather pattern at scale, and the
  reason MR's ephemeral bill dominates (Table 2: EC costs 772x XDT here).

Parameters are calibrated so the per-backend speedups and cost ratios land
on the paper's Fig. 7 / Table 2 anchors (see tests/test_workloads.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Union

from .cluster import DEFAULT_NET, NetConstants
from .cost import CostBreakdown, WorkflowCostInputs
from .dag import (
    AdaptiveRoute,
    Edge,
    RoutePolicy,
    SizeRoute,
    Stage,
    WorkflowDAG,
)
from .topology import Topology, Zone

#: the paper's single-backend configurations
BACKENDS = ("s3", "elasticache", "xdt")
#: ... plus the per-edge-routed configurations (Fig 7 / Table 2 extra
#: columns): ``hybrid`` routes from static edge facts (SizeRoute),
#: ``adaptive`` from the telemetry feed (AdaptiveRoute)
ROUTED_BACKENDS = BACKENDS + ("hybrid", "adaptive")

#: The default per-edge policy behind ``backend="hybrid"``: objects that fit
#: the activator's inline payload cap ride the control message (no storage
#: bill, one fewer hop), bulk objects move producer->consumer over XDT, and
#: edges whose producer is marked evictable fall back to durable S3.
HYBRID_ROUTE = SizeRoute(inline_under=DEFAULT_NET.inline_limit)


@dataclasses.dataclass
class WorkloadResult:
    backend: str
    latency_s: float
    breakdown: Dict[str, float]          # phase -> seconds (critical path)
    cost: CostBreakdown
    inputs: WorkflowCostInputs
    #: per-edge attribution: medium, objects/bytes moved, transfer seconds,
    #: and this edge's share of the storage bill (micro-USD)
    edges: Optional[Dict[str, Dict[str, Any]]] = None
    #: edge label -> medium summary ("s3", "xdt", "inline+xdt", ...)
    edge_media: Optional[Dict[str, str]] = None


# ---------------------------------------------------------------------------
# VID — Video Analytics: streaming -> decoder -> scatter(recognizers)
# ---------------------------------------------------------------------------

VID_FRAGMENT = 30 << 20          # video fragment, streaming -> decoder
VID_FRAME_BATCH = 3 << 20        # decoded frames, decoder -> each recognizer
VID_FAN = 4
VID_COMPUTE = {"streaming": 0.05, "decoder": 0.35, "recognition": 0.40}

VID_DAG = WorkflowDAG(
    "vid",
    stages=[
        Stage("streaming", compute_s=VID_COMPUTE["streaming"]),
        Stage("decoder", compute_s=VID_COMPUTE["decoder"]),
        Stage("recognition", fan=VID_FAN, compute_s=VID_COMPUTE["recognition"]),
    ],
    edges=[
        Edge("streaming", "decoder", VID_FRAGMENT, label="fragment",
             handoff="sync"),
        Edge("decoder", "recognition", VID_FRAME_BATCH, label="frames",
             handoff="sync"),
    ],
)


def _vid_breakdown(marks: Dict[str, float], total: float) -> Dict[str, float]:
    return {
        "streaming_compute": VID_COMPUTE["streaming"],
        "fragment_transfer": marks["edge:fragment"] - VID_COMPUTE["streaming"],
        "decode_compute": VID_COMPUTE["decoder"],
        "frames_transfer": marks["edge:frames"] - marks["compute:decoder"],
        "recognition_compute": total - marks["edge:frames"],
    }


# ---------------------------------------------------------------------------
# SET — Stacking Ensemble Training: broadcast(chunked dataset) -> gather
# ---------------------------------------------------------------------------

SET_K = 8                         # trainers
SET_CHUNKS = 8                    # dataset objects (chunked, sync-SDK gets)
SET_CHUNK_BYTES = 8 << 20         # 8 MB -> 64 MB dataset
SET_MODEL_BYTES = 1 << 20         # trained model + fold predictions
SET_CONCURRENCY = 1               # sync SDK: sequential gets per trainer
SET_COMPUTE = {"driver": 0.05, "trainer": 0.10, "reconcile": 0.10}

SET_DAG = WorkflowDAG(
    "set",
    stages=[
        Stage("driver", compute_s=SET_COMPUTE["driver"],
              gather_compute_s=SET_COMPUTE["reconcile"]),
        Stage("trainer", fan=SET_K, compute_s=SET_COMPUTE["trainer"],
              blocking=False),
    ],
    edges=[
        Edge("driver", "trainer", SET_CHUNK_BYTES, label="dataset",
             handoff="staged", fanout="broadcast", n_objects=SET_CHUNKS,
             concurrency=SET_CONCURRENCY),
        Edge("trainer", "driver", SET_MODEL_BYTES, label="models",
             handoff="staged", fanout="partition", concurrency=0),
    ],
)


def _set_breakdown(marks: Dict[str, float], total: float) -> Dict[str, float]:
    return {
        "driver_compute": SET_COMPUTE["driver"],
        "broadcast_dataset": marks["edge:dataset"] - SET_COMPUTE["driver"],
        "train_compute": marks["gather_start"] - marks["edge:dataset"],
        "gather_models": marks["gather_done"] - marks["gather_start"],
        "reconcile_compute": SET_COMPUTE["reconcile"],
    }


# ---------------------------------------------------------------------------
# MR — MapReduce aggregation query: S3 input -> shuffle(backend) -> reduce
# ---------------------------------------------------------------------------

MR_M = 8                          # mappers
MR_R = 8                          # reducers
MR_INPUT_BYTES = 240 << 20        # per-mapper input (always via S3)
MR_SLICE_BYTES = 8 << 20          # per (mapper, reducer) shuffle slice
MR_COMPUTE = {"driver": 0.02, "mapper": 0.55, "reducer": 0.55}

MR_DAG = WorkflowDAG(
    "mr",
    stages=[
        Stage("driver", compute_s=MR_COMPUTE["driver"]),
        Stage("mapper", fan=MR_M, compute_s=MR_COMPUTE["mapper"],
              blocking=False),
        Stage("reducer", fan=MR_R, compute_s=MR_COMPUTE["reducer"],
              blocking=False),
    ],
    edges=[
        # original input is NEVER optimized by XDT: pinned to S3
        Edge(None, "mapper", MR_INPUT_BYTES, label="input", route="s3",
             handoff="external"),
        Edge("mapper", "reducer", MR_SLICE_BYTES, label="shuffle",
             handoff="staged", fanout="partition", concurrency=1),
    ],
)


def _mr_breakdown(marks: Dict[str, float], total: float) -> Dict[str, float]:
    return {
        "input_read_s3": marks["edge:input"] - MR_COMPUTE["driver"],
        "map_compute": MR_COMPUTE["mapper"],
        "mapper_put": (
            marks["staged:shuffle"] - marks["edge:input"] - MR_COMPUTE["mapper"]
        ),
        "reducer_get": marks["edge:shuffle"] - marks["staged:shuffle"],
        "reduce_compute": total - marks["edge:shuffle"],
    }


# ---------------------------------------------------------------------------
# Topology workloads (Fig 14): placement across the edge-cloud continuum.
# These live in their own registries (TOPO_WORKLOADS / TOPO_DAGS /
# TOPOLOGIES) so the flat-cluster figure sweeps (Fig 7 / Table 2 goldens
# iterate WORKLOADS / DAGS) are untouched.
# ---------------------------------------------------------------------------

# EDGE — edge-ingest -> cloud-train fan-in.  Four ingest instances are
# pinned one-per-edge-site, the trainer is pinned to the cloud zone; the
# interesting decision is the unpinned driver (collector).  Naive
# round-robin drops it on edge-0 (zone 0), so the model gather and every
# service-homed leg cross the edge uplink; tier-aware placement homes it
# in the cloud zone next to the trainer and the storage service.

EDGE_FAN = 4                      # ingest sites
EDGE_SENSOR_BYTES = 2 << 20       # raw sensor batch, read from storage
EDGE_SAMPLE_BYTES = 6 << 20       # featurized samples, ingest -> train
EDGE_MODEL_BYTES = 8 << 20        # model checkpoint, train -> driver
EDGE_COMPUTE = {"driver": 0.02, "ingest": 0.08, "train": 0.45,
                "publish": 0.05}

EDGE_DAG = WorkflowDAG(
    "edge",
    stages=[
        Stage("driver", compute_s=EDGE_COMPUTE["driver"],
              gather_compute_s=EDGE_COMPUTE["publish"]),
        Stage("ingest", fan=EDGE_FAN, compute_s=EDGE_COMPUTE["ingest"],
              blocking=False),
        Stage("train", compute_s=EDGE_COMPUTE["train"], blocking=False),
    ],
    edges=[
        # raw sensor data is original input: always via durable storage
        Edge(None, "ingest", EDGE_SENSOR_BYTES, label="sensor", route="s3",
             handoff="external"),
        Edge("ingest", "train", EDGE_SAMPLE_BYTES, label="samples",
             handoff="staged", fanout="partition", concurrency=1),
        Edge("train", "driver", EDGE_MODEL_BYTES, label="model",
             handoff="staged", fanout="partition", concurrency=0),
    ],
)

EDGE_CLOUD_TOPOLOGY = Topology(
    zones=(
        Zone("edge-0", region="site-0", site="edge"),
        Zone("edge-1", region="site-1", site="edge"),
        Zone("edge-2", region="site-2", site="edge"),
        Zone("edge-3", region="site-3", site="edge"),
        Zone("cloud", region="us-east", site="cloud"),
    ),
    pin={
        "ingest": ("edge-0", "edge-1", "edge-2", "edge-3"),
        "train": "cloud",
    },
)


def _edge_breakdown(marks: Dict[str, float], total: float) -> Dict[str, float]:
    ingest_done = marks.get("edge:samples", 0.0)
    gather_start = marks.get("gather_start", total)
    return {
        "ingest_and_upload": ingest_done,
        "train_compute": gather_start - ingest_done,
        "gather_model": total - gather_start,
    }


# GEO — geo-sharded fan-in.  Six shard instances are pinned round-robin
# across one same-region zone and two remote regions; the unpinned driver
# broadcasts the query and gathers partials.  Naive round-robin puts the
# driver in the hub zone, which is right for service-homed backends (the
# storage service lives there) but wrong for direct media: tier-aware
# placement with backend="xdt" co-locates the driver with the us-shard
# replicas and saves two cross-zone legs per round.

GEO_SHARDS = 6
GEO_QUERY_BYTES = 3 << 20         # broadcast query/plan, driver -> shards
GEO_PARTIAL_BYTES = 10 << 20      # partial aggregates, shard -> driver
GEO_N_QUERY_OBJECTS = 2           # chunked plan (two objects per shard)
GEO_COMPUTE = {"driver": 0.03, "shard": 0.25, "merge": 0.08}

GEO_DAG = WorkflowDAG(
    "geo",
    stages=[
        Stage("driver", compute_s=GEO_COMPUTE["driver"],
              gather_compute_s=GEO_COMPUTE["merge"]),
        Stage("shard", fan=GEO_SHARDS, compute_s=GEO_COMPUTE["shard"],
              blocking=False),
    ],
    edges=[
        Edge("driver", "shard", GEO_QUERY_BYTES, label="query",
             handoff="staged", fanout="broadcast",
             n_objects=GEO_N_QUERY_OBJECTS, concurrency=1),
        Edge("shard", "driver", GEO_PARTIAL_BYTES, label="partials",
             handoff="staged", fanout="partition", concurrency=0),
    ],
)

GEO_TOPOLOGY = Topology(
    zones=(
        Zone("us-hub", region="us"),
        Zone("us-shard", region="us"),
        Zone("eu-shard", region="eu"),
        Zone("ap-shard", region="ap"),
    ),
    pin={"shard": ("us-shard", "eu-shard", "ap-shard")},
)


def _geo_breakdown(marks: Dict[str, float], total: float) -> Dict[str, float]:
    query_done = marks.get("edge:query", 0.0)
    gather_start = marks.get("gather_start", total)
    return {
        "broadcast_query": query_done,
        "shard_compute": gather_start - query_done,
        "gather_partials": total - gather_start,
    }


# ---------------------------------------------------------------------------
# shared tail: DAG execution + result assembly
# ---------------------------------------------------------------------------


def _run_workload(
    dag: WorkflowDAG,
    breakdown_fn: Callable[[Dict[str, float], float], Dict[str, float]],
    backend: Union[str, RoutePolicy],
    net: NetConstants,
    seed: int,
    deterministic: bool,
    topology: Optional[Topology] = None,
    plan: Any = None,
) -> WorkloadResult:
    if backend == "hybrid":
        route: Union[str, RoutePolicy] = HYBRID_ROUTE
        label = "hybrid"
    elif backend == "adaptive":
        # fresh policy per run: the telemetry feed starts empty (static
        # fallback) and adapts within the run as edges are observed
        route, label = AdaptiveRoute(), "adaptive"
    elif isinstance(backend, RoutePolicy):
        route, label = backend, backend.describe()
    else:
        route = label = backend
    run = dag.compile(
        target="cluster", backend=route, net=net, topology=topology, plan=plan
    ).run(seed=seed, deterministic=deterministic)
    return WorkloadResult(
        backend=label,
        latency_s=run.latency_s,
        breakdown=breakdown_fn(run.marks, run.latency_s),
        cost=run.cost(),
        inputs=run.cost_inputs(),
        edges=run.edge_cost_rows(),
        edge_media=run.edge_media,
    )


def run_vid(backend: Union[str, RoutePolicy], net: NetConstants = DEFAULT_NET,
            seed: int = 0, deterministic: bool = False) -> WorkloadResult:
    return _run_workload(VID_DAG, _vid_breakdown, backend, net, seed,
                         deterministic)


def run_set(backend: Union[str, RoutePolicy], net: NetConstants = DEFAULT_NET,
            seed: int = 0, deterministic: bool = False) -> WorkloadResult:
    return _run_workload(SET_DAG, _set_breakdown, backend, net, seed,
                         deterministic)


def run_mr(backend: Union[str, RoutePolicy], net: NetConstants = DEFAULT_NET,
           seed: int = 0, deterministic: bool = False) -> WorkloadResult:
    return _run_workload(MR_DAG, _mr_breakdown, backend, net, seed,
                         deterministic)


def run_edge(backend: Union[str, RoutePolicy], net: NetConstants = DEFAULT_NET,
             seed: int = 0, deterministic: bool = False,
             topology: Optional[Topology] = EDGE_CLOUD_TOPOLOGY,
             plan: Any = None) -> WorkloadResult:
    return _run_workload(EDGE_DAG, _edge_breakdown, backend, net, seed,
                         deterministic, topology=topology, plan=plan)


def run_geo(backend: Union[str, RoutePolicy], net: NetConstants = DEFAULT_NET,
            seed: int = 0, deterministic: bool = False,
            topology: Optional[Topology] = GEO_TOPOLOGY,
            plan: Any = None) -> WorkloadResult:
    return _run_workload(GEO_DAG, _geo_breakdown, backend, net, seed,
                         deterministic, topology=topology, plan=plan)


WORKLOADS = {"vid": run_vid, "set": run_set, "mr": run_mr}
DAGS = {"vid": VID_DAG, "set": SET_DAG, "mr": MR_DAG}

#: Fig 14 registries — separate from WORKLOADS/DAGS on purpose: the flat
#: figure sweeps and sha goldens iterate those and must not grow cells.
TOPO_WORKLOADS = {"edge": run_edge, "geo": run_geo}
TOPO_DAGS = {"edge": EDGE_DAG, "geo": GEO_DAG}
TOPOLOGIES = {"edge": EDGE_CLOUD_TOPOLOGY, "geo": GEO_TOPOLOGY}


def run_all(deterministic: bool = True, seed: int = 0, backends=BACKENDS):
    """{workload: {backend: WorkloadResult}} across the full matrix."""
    return {
        name: {b: fn(b, seed=seed, deterministic=deterministic) for b in backends}
        for name, fn in WORKLOADS.items()
    }
