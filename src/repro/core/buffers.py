"""Producer-side ephemeral buffer registry with refcounted retrievals.

Paper §4: the producer's SDK/queue-proxy "buffers the payload in its memory";
each reference carries "a user-specified number of retrievals N of that object,
which complete before the object can be de-allocated"; buffer lifetime is tied
to the producer *instance* lifetime (keep-alive), and instance shutdown
immediately de-allocates all objects (consumers observe ``XDT.ProducerGone``).

Flow control (paper §5.3): the design relies on pre-allocated buffer slots;
when none are free "the subsequent transfers are paused, resulting in the user
code blocking in the corresponding XDT API call."  We model slots as a bounded
byte/slot budget; ``put(block=True)`` waits on a condition variable that is
notified by completing retrievals, ``put(block=False)`` raises
:class:`XDTWouldBlock` (TCP-backpressure analogue without a TCP stack).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from .errors import (
    XDTObjectExhausted,
    XDTProducerGone,
    XDTTimeout,
    XDTWouldBlock,
)


def _default_nbytes(obj: Any) -> int:
    nb = getattr(obj, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(obj, (bytes, bytearray)):
        return len(obj)
    return 64  # opaque python object: accounting floor


# Entry layout: a plain list (C-speed construction on the put hot path —
# a slotted dataclass costs a Python-level __init__ frame per put).  Indexed
# by the _E_* constants below; private to this module and the fused fast
# paths in repro.core.transfer.
_E_OBJ, _E_NBYTES, _E_REMAINING, _E_EPOCH, _E_CREATED = range(5)


def _Entry(obj: Any, nbytes: int, remaining: int, epoch: int,
           created_at: float) -> list:
    return [obj, nbytes, remaining, epoch, created_at]


@dataclasses.dataclass(frozen=True)
class RegistryStats:
    puts: int
    gets: int
    evictions: int
    bytes_in_use: int
    slots_in_use: int
    high_water_bytes: int
    blocked_puts: int


class BufferRegistry:
    """Bounded, refcounted, epoch-guarded ephemeral object store.

    Thread-safe: the serving engine and the data pipeline pull from worker
    threads while producers keep running.
    """

    def __init__(
        self,
        max_slots: int = 256,
        max_bytes: int = 1 << 34,
        clock: Callable[[], float] = time.monotonic,
        threadsafe: bool = True,
    ):
        self._lock = threading.Lock()
        self._space = threading.Condition(self._lock)
        #: single-owner mode (``threadsafe=False``): the registry belongs to
        #: one thread (the virtual-time workflow engine), so ``put``/``get``
        #: skip the lock/condition protocol entirely.  Blocking flow control
        #: is meaningless there — the consumer that would free a slot runs on
        #: the same thread — so a full registry raises instead of waiting.
        self._threadsafe = threadsafe
        self._entries: Dict[int, _Entry] = {}
        self._next_id = 0
        self._epoch = 0
        self._max_slots = max_slots
        self._max_bytes = max_bytes
        self._bytes = 0
        self._clock = clock
        self._puts = 0
        self._gets = 0
        self._evictions = 0
        self._high_water = 0
        self._blocked_puts = 0

    # ------------------------------------------------------------------ put
    def put(
        self,
        obj: Any,
        n_retrievals: int = 1,
        nbytes: Optional[int] = None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> Tuple[int, int]:
        """Buffer ``obj`` for ``n_retrievals`` pulls.  Returns (buffer_id, epoch)."""
        if n_retrievals < 1:
            raise ValueError("n_retrievals must be >= 1")
        nb = _default_nbytes(obj) if nbytes is None else int(nbytes)
        if not self._threadsafe:
            return self._put_unlocked(obj, n_retrievals, nb, block)
        deadline = None if timeout is None else self._clock() + timeout
        with self._space:
            while not self._has_room(nb):
                if not block:
                    raise XDTWouldBlock(
                        f"no buffer slot for {nb}B "
                        f"({len(self._entries)}/{self._max_slots} slots, "
                        f"{self._bytes}/{self._max_bytes}B)"
                    )
                self._blocked_puts += 1
                remaining = None if deadline is None else deadline - self._clock()
                if remaining is not None and remaining <= 0:
                    raise XDTTimeout("put() flow-control wait exceeded timeout")
                if not self._space.wait(timeout=remaining):
                    raise XDTTimeout("put() flow-control wait exceeded timeout")
            buffer_id = self._next_id
            self._next_id += 1
            self._entries[buffer_id] = [
                obj, nb, n_retrievals, self._epoch, self._clock(),
            ]
            self._bytes += nb
            self._high_water = max(self._high_water, self._bytes)
            self._puts += 1
            return buffer_id, self._epoch

    def _put_unlocked(
        self, obj: Any, n_retrievals: int, nb: int, block: bool
    ) -> Tuple[int, int]:
        if not self._has_room(nb):
            if not block:
                raise XDTWouldBlock(
                    f"no buffer slot for {nb}B "
                    f"({len(self._entries)}/{self._max_slots} slots, "
                    f"{self._bytes}/{self._max_bytes}B)"
                )
            self._blocked_puts += 1
            raise XDTTimeout(
                "put() flow control cannot unblock in single-owner mode "
                "(the consumer that would free a slot runs on this thread)"
            )
        buffer_id = self._next_id
        self._next_id += 1
        self._entries[buffer_id] = [
            obj, nb, n_retrievals, self._epoch, self._clock(),
        ]
        self._bytes += nb
        if self._bytes > self._high_water:
            self._high_water = self._bytes
        self._puts += 1
        return buffer_id, self._epoch

    def _has_room(self, nb: int) -> bool:
        if len(self._entries) >= self._max_slots:
            return False
        # A single object larger than the budget is still admitted when the
        # registry is otherwise empty (mirrors streaming a >buffer object
        # chunk-by-chunk through TCP: it is slow, not impossible).
        if self._bytes + nb > self._max_bytes and self._entries:
            return False
        return True

    # ------------------------------------------------------------------ get
    def get(self, buffer_id: int, epoch: int) -> Any:
        """One retrieval.  Decrements the refcount; frees on the Nth pull."""
        if not self._threadsafe:
            if epoch != self._epoch:
                raise XDTProducerGone(
                    f"producer epoch {epoch} superseded by {self._epoch}"
                )
            entry = self._entries.get(buffer_id)
            if entry is None:
                raise XDTObjectExhausted(f"buffer {buffer_id} not resident")
            obj = entry[_E_OBJ]
            entry[_E_REMAINING] = remaining = entry[_E_REMAINING] - 1
            self._gets += 1
            if remaining == 0:
                self._bytes -= entry[_E_NBYTES]
                del self._entries[buffer_id]
            return obj
        with self._space:
            if epoch != self._epoch:
                raise XDTProducerGone(
                    f"producer epoch {epoch} superseded by {self._epoch}"
                )
            entry = self._entries.get(buffer_id)
            if entry is None:
                raise XDTObjectExhausted(f"buffer {buffer_id} not resident")
            obj = entry[_E_OBJ]
            entry[_E_REMAINING] = remaining = entry[_E_REMAINING] - 1
            self._gets += 1
            if remaining == 0:
                self._release(buffer_id)
            return obj

    def peek_remaining(self, buffer_id: int) -> int:
        with self._lock:
            e = self._entries.get(buffer_id)
            return 0 if e is None else e[_E_REMAINING]

    def _release(self, buffer_id: int) -> None:
        entry = self._entries.pop(buffer_id)
        self._bytes -= entry[_E_NBYTES]
        self._space.notify_all()

    # ----------------------------------------------------- instance lifetime
    def kill_instance(self) -> int:
        """Simulate producer instance shutdown (keep-alive expiry / failure).

        All resident objects are dropped and the epoch advances, so any
        outstanding reference observes :class:`XDTProducerGone` on ``get``.
        Returns the number of evicted objects.
        """
        with self._space:
            n = len(self._entries)
            self._entries.clear()
            self._bytes = 0
            self._epoch += 1
            self._evictions += n
            self._space.notify_all()
            return n

    def expire_older_than(self, age_s: float) -> int:
        """Garbage-collect objects past a TTL (defensive sweep; the paper's
        design frees on the Nth retrieval, this guards leaked refs)."""
        with self._space:
            now = self._clock()
            stale = [
                bid
                for bid, e in self._entries.items()
                if now - e[_E_CREATED] > age_s
            ]
            for bid in stale:
                self._release(bid)
            self._evictions += len(stale)
            return len(stale)

    # ------------------------------------------------------------ inspection
    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def stats(self) -> RegistryStats:
        with self._lock:
            return RegistryStats(
                puts=self._puts,
                gets=self._gets,
                evictions=self._evictions,
                bytes_in_use=self._bytes,
                slots_in_use=len(self._entries),
                high_water_bytes=self._high_water,
                blocked_puts=self._blocked_puts,
            )
