"""Shared plugin registry for the three extension surfaces.

``register_backend`` (transfer media), ``register_pass`` (graph-optimizer
passes), and ``register_autoscaler`` (scale-up policies) grew up separately
and each hand-rolled the same dict-plus-validation shape.  They now share
one :class:`Registry` with a single duplicate-name policy and an
introspectable listing — **without changing any public call site**: the
``register_*`` functions keep their modules, names, and signatures, and the
``available_*`` helpers keep returning plain name tuples.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

__all__ = ["Registry"]


class Registry:
    """Name -> class registry with an explicit duplicate policy.

    * ``on_duplicate="replace"`` (default) — re-registering a name
      overwrites, so module reloads and idempotent plugin imports stay
      cheap.  This is the historical behavior of all three surfaces.
    * ``on_duplicate="error"`` — re-registering a *different* class under a
      taken name raises; re-registering the same class is a no-op.

    The mapping protocol mirrors the plain dicts this replaces: ``in``,
    ``[]``, ``.get``, iteration (insertion order), ``len``.
    """

    def __init__(self, kind: str, on_duplicate: str = "replace") -> None:
        if on_duplicate not in ("replace", "error"):
            raise ValueError(f"unknown duplicate policy {on_duplicate!r}")
        self.kind = kind
        self.on_duplicate = on_duplicate
        self._entries: Dict[str, type] = {}

    def register(self, cls: type, name: Optional[str] = None) -> type:
        """Register ``cls`` under ``name`` (default: ``cls.name``).

        Returns ``cls`` so it can be used as a decorator.
        """
        key = name if name is not None else getattr(cls, "name", "")
        if not key or not isinstance(key, str):
            raise ValueError(
                f"{self.kind} class {cls!r} needs a non-empty string `name`"
            )
        prev = self._entries.get(key)
        if prev is not None and prev is not cls and self.on_duplicate == "error":
            raise ValueError(
                f"{self.kind} name {key!r} already registered to {prev!r}"
            )
        self._entries[key] = cls
        return cls

    # -- mapping protocol (drop-in for the former module-level dicts) -----
    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __getitem__(self, name: str) -> type:
        return self._entries[name]

    def get(self, name: str, default=None):
        return self._entries.get(name, default)

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def names(self) -> Tuple[str, ...]:
        return tuple(self._entries)

    def entries(self) -> Dict[str, type]:
        """A snapshot copy — mutating it does not touch the registry."""
        return dict(self._entries)

    def describe(self) -> Dict[str, str]:
        """Introspectable listing: name -> implementing class."""
        return {
            n: f"{c.__module__}.{c.__qualname__}"
            for n, c in self._entries.items()
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Registry({self.kind!r}, {list(self._entries)!r})"
