"""Graph optimizer for declarative workflow DAGs.

The paper's cost argument (and Table 2) is about picking the cheapest
*medium* per edge; DataFlower (arXiv:2304.14629) and "Following the Data,
Not the Function" (arXiv:2109.13492) show the next rung on that ladder:
restructure the **graph** around data locality, because the cheapest
transfer of all is the one that never leaves the instance.  This module is
that rung — ``dag.optimize(passes=...)`` rewrites a
:class:`~repro.core.dag.WorkflowDAG` and emits a :class:`PlacementPlan`
both lowerings honor:

:class:`SyncChainFusion` (``"fuse"``)
    Merges chains of 1:1 sync edges into one fused stage: the handoff's
    object never crosses a process boundary, so the transfer disappears
    entirely — zero fee, zero ref, compute summed, one fewer invocation.
    Fusion is *refused* across evictable, external, and fan boundaries (and
    across incompatible scaling policies when a policy factory is given):
    merging those would change semantics, not just cost.

:class:`CoPlacement` (``"coplace"``)
    Emits producer->consumer affinity hints for edges whose every consumer
    pulls from a single producer instance.  The scheduler's steering honors
    the hint (``Deployment.steer(prefer=...)``: land the consumer on the
    producer's node when slots allow) and both lowerings model the locality
    discount — a co-placed XDT pull moves through shared memory instead of
    the producer NIC (:meth:`ServerlessCluster.local_pull`,
    ``TransferEngine.get(local=True)``).

:class:`PredictiveSpill` (``"spill"``)
    Closes the ROADMAP loop "feed cold-start/reap telemetry into routing":
    reads :class:`~repro.core.telemetry.DeploymentTelemetry` reap and
    cold-start windows and rewrites staged edges onto durable media when
    the producer's predicted keep-alive expiry precedes the consumer's
    predicted pull — paying one storage fee up front instead of the
    producer-death retry penalty (re-running the whole producer subtree).
    With no telemetry feed the pass is a no-op: spilling is never guessed
    from an empty window.

The un-optimized path is untouched: ``optimize`` builds *new* ``WorkflowDAG``
objects (stages and edges are frozen), and a run without a plan executes
bit-for-bit as before — the sha-fingerprint goldens in ``tests/test_dag.py``
still hold.

Usage::

    opt_dag, plan = dag.optimize()                 # fuse + coplace (+ spill)
    run = opt_dag.compile(target="cluster", backend="xdt", plan=plan).run()
    binding = opt_dag.compile(target="engine", engine=engine, plan=plan)

Custom passes subclass :class:`GraphPass` and register with
:func:`register_pass`; ``optimize(passes=("fuse", "mypass"))`` then selects
them by name.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, ClassVar, Dict, List, Optional, Sequence, Tuple, Type, Union

from .cluster import DEFAULT_NET, NetConstants
from .cost import egress_fee_usd
from .dag import Edge, Stage, WorkflowDAG
from .registry import Registry
from .scheduler import ScalingPolicy
from .telemetry import TelemetryHub
from .topology import Topology

#: media a spilled edge may be pinned to (survive producer instance death)
DURABLE_MEDIA = ("s3", "elasticache")


@dataclasses.dataclass
class PlacementPlan:
    """What the optimizer decided, for the lowerings (and humans) to read.

    ``affinity`` maps consumer stage -> producer stage to co-place with;
    ``fused`` maps each fused stage to the original chain it replaced;
    ``eliminated`` maps each removed edge label to the fused stage that
    absorbed it; ``spilled`` maps rewritten edge labels to the durable
    medium they were pinned to.  ``zones`` maps stages to the zone a
    tier-aware :class:`CoPlacement` chose for them (workload pins always
    win — see :meth:`~repro.core.topology.Topology.assign_stage_zones`);
    ``contention_aware`` asks the lowerings to route pulls around a
    saturated shared-memory channel at pull time.  ``notes`` is the
    per-pass provenance — including every *refused* rewrite and why."""

    affinity: Dict[str, str] = dataclasses.field(default_factory=dict)
    fused: Dict[str, Tuple[str, ...]] = dataclasses.field(default_factory=dict)
    eliminated: Dict[str, str] = dataclasses.field(default_factory=dict)
    spilled: Dict[str, str] = dataclasses.field(default_factory=dict)
    zones: Dict[str, str] = dataclasses.field(default_factory=dict)
    contention_aware: bool = False
    notes: List[str] = dataclasses.field(default_factory=list)

    def is_noop(self) -> bool:
        return not (self.affinity or self.fused or self.spilled or self.zones)

    def rename_stage(self, old: str, new: str) -> None:
        """Keep plan entries coherent when a pass renames/merges stages."""
        affinity = self.affinity
        if old in affinity:
            affinity[new] = affinity.pop(old)
        for k, v in list(affinity.items()):
            if v == old:
                affinity[k] = new
        # a consumer fused into its own affinity producer needs no hint
        for k in [k for k, v in affinity.items() if k == v]:
            del affinity[k]
        # edges eliminated into a stage that fused again must point at the
        # stage's final name (chains of 3+ re-fuse their intermediate)
        for k, v in self.eliminated.items():
            if v == old:
                self.eliminated[k] = new
        if old in self.zones:
            self.zones.setdefault(new, self.zones.pop(old))

    def describe(self) -> str:
        parts = []
        if self.fused:
            parts.append("fused " + ", ".join(
                f"{'+'.join(v)}" for v in self.fused.values()
            ))
        if self.affinity:
            parts.append("co-place " + ", ".join(
                f"{c}@{p}" for c, p in sorted(self.affinity.items())
            ))
        if self.spilled:
            parts.append("spill " + ", ".join(
                f"{e}->{m}" for e, m in sorted(self.spilled.items())
            ))
        if self.zones:
            parts.append("zone " + ", ".join(
                f"{s}:{z}" for s, z in sorted(self.zones.items())
            ))
        if self.contention_aware:
            parts.append("contention-aware pulls")
        return "; ".join(parts) if parts else "no-op"


class GraphPass:
    """One graph-rewriting pass: ``apply`` returns a (new) DAG + the plan."""

    name: ClassVar[str] = ""

    def apply(
        self, dag: WorkflowDAG, plan: PlacementPlan
    ) -> Tuple[WorkflowDAG, PlacementPlan]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Pass 1: sync-chain fusion
# ---------------------------------------------------------------------------


class SyncChainFusion(GraphPass):
    """Fuse chains of 1:1 sync edges into single stages.

    A sync handoff between two fan-1 blocking stages is the paper's 1-1
    pattern; fused, the object never leaves the producer's address space —
    the edge is deleted outright (no put, no ref, no fee, no transfer
    seconds) and the stages' compute is summed into one invocation.

    Refusal guards (each recorded in ``plan.notes``):

    * **fan boundary** — scatter/gather edges need distinct instances;
    * **evictable boundary** — an evictable stage's reclamation semantics
      must not silently extend to the code fused into it;
    * **external boundary** — original inputs predate the workflow and
      cannot be fused away (``src=None`` edges are not chains at all);
    * **side edges** — only true linear chain links fuse: a producer with
      other out-edges (a sibling consumer, a second sync child) would have
      that work serialized behind the fused compute — fusion must never
      *slow* the graph;
    * **orchestrated consumer / gather epilogue** — fusion targets vSwarm
      blocking chains, where the producer's billed span already covers the
      consumer;
    * **incompatible scaling policies** — when a ``scaling`` factory is
      supplied, stages that would be deployed with different policies keep
      their own deployments.
    """

    name = "fuse"

    def __init__(
        self,
        scaling: Optional[Callable[[Stage], ScalingPolicy]] = None,
    ):
        self.scaling = scaling

    def _refusal(self, dag: WorkflowDAG, e: Edge) -> Optional[str]:
        if e.dst == dag.entry.name:
            return "gather edge into the entry"
        p, c = dag.by_name[e.src], dag.by_name[e.dst]
        if p.fan != 1 or c.fan != 1:
            return f"fan boundary ({p.fan}->{c.fan})"
        if p.evictable or c.evictable:
            return "evictable boundary"
        if not c.blocking:
            return "orchestrated consumer"
        if c.gather_compute_s > 0:
            return "consumer has a gather epilogue"
        ins = dag.in_edges(c)
        if len(ins) != 1 or ins[0] is not e:
            return "consumer has other in-edges"
        outs = dag.out_edges(p)
        if len(outs) != 1 or outs[0] is not e:
            # fusing would serialize the producer's other consumers behind
            # the fused compute (puts happen after compute): only true
            # linear chain links fuse, or the pass could *slow* the graph
            return "producer has other out-edges"
        if self.scaling is not None and self.scaling(p) != self.scaling(c):
            return "incompatible scaling policies"
        return None

    def _fuse(
        self, dag: WorkflowDAG, plan: PlacementPlan, e: Edge
    ) -> WorkflowDAG:
        p, c = dag.by_name[e.src], dag.by_name[e.dst]
        fused_name = f"{p.name}+{c.name}"
        if fused_name in dag.by_name:
            raise ValueError(f"fused stage name {fused_name!r} collides")
        fused = Stage(
            name=fused_name,
            fan=1,
            compute_s=p.compute_s + c.compute_s,
            gather_compute_s=p.gather_compute_s,
            blocking=p.blocking,
            evictable=False,
        )
        stages = [
            fused if s.name == p.name else s
            for s in dag.stages if s.name != c.name
        ]
        edges = []
        for ed in dag.edges:
            if ed is e:
                continue
            src = fused_name if ed.src in (p.name, c.name) else ed.src
            dst = fused_name if ed.dst in (p.name, c.name) else ed.dst
            if src != ed.src or dst != ed.dst:
                ed = dataclasses.replace(ed, src=src, dst=dst)
            edges.append(ed)
        chain = (
            plan.fused.pop(p.name, (p.name,))
            + plan.fused.pop(c.name, (c.name,))
        )
        plan.fused[fused_name] = chain
        plan.eliminated[e.label] = fused_name
        plan.rename_stage(p.name, fused_name)
        plan.rename_stage(c.name, fused_name)
        plan.notes.append(
            f"fuse: {p.name}+{c.name} — edge {e.label!r} eliminated "
            f"({e.nbytes}B sync handoff never leaves the instance)"
        )
        return WorkflowDAG(dag.name, stages, edges)

    def apply(self, dag, plan):
        while True:
            refusals = []
            fused_one = False
            for e in dag.edges:
                if e.handoff != "sync" or e.src is None:
                    continue
                reason = self._refusal(dag, e)
                if reason is not None:
                    refusals.append(f"fuse: {e.label!r} refused ({reason})")
                    continue
                dag = self._fuse(dag, plan, e)
                fused_one = True
                break
            if not fused_one:
                plan.notes.extend(refusals)
                return dag, plan


# ---------------------------------------------------------------------------
# Pass 2: producer/consumer co-placement
# ---------------------------------------------------------------------------


class CoPlacement(GraphPass):
    """Emit producer->consumer affinity hints for single-producer edges.

    Steering consumers onto their producer's node turns the edge's XDT
    pulls into shared-memory copies — the locality discount both lowerings
    model (:meth:`ServerlessCluster.local_pull`, ``ctx.get(local=True)``).
    Only edges where every consumer instance pulls from **one** producer
    instance qualify (producer fan 1: the paper's 1-1, scatter, and
    broadcast patterns); a shuffle's consumers pull from every producer and
    cannot sit next to all of them.  ``slots_per_node`` bounds how many
    consumer instances one producer node is asked to host — beyond it, the
    hint is withheld ("prefer when slots allow" starts at the plan).

    **Tier-aware placement** (``topology=``): before emitting affinity
    hints, every unpinned stage is greedily assigned the zone minimizing
    its tier-crossing bill against already-placed neighbors — cost is
    lexicographic ``(egress USD, tier seconds)``, so the optimizer never
    trades fees for speed, and ties break on the lowest zone index (fully
    deterministic).  Workload pins are hard constraints and consume no
    decision; the chosen zones land in ``plan.zones`` for
    ``Topology.assign_stage_zones`` to honor.  ``backend`` is the run's
    intended default route — a string medium makes ``route="default"``
    edges price as service-homed (S3/ElastiCache: producer->service +
    service->consumer legs) vs instance-resident (direct
    producer->consumer leg); policies and ``None`` price the direct leg,
    which keeps producers and consumers together — the safe default.
    Affinity hints are additionally gated to same-zone pairs: a consumer
    cannot sit on a node in another zone.

    ``contention_aware=True`` sets ``plan.contention_aware``: at pull
    time the cluster lowering compares the shared-memory FIFO backlog
    against the producer-NIC path and routes around a saturated memory
    channel, splitting hot broadcasts across the two same-zone paths.

    The DAG itself is unchanged; decisions land in ``plan.affinity`` /
    ``plan.zones`` / ``plan.contention_aware``.
    """

    name = "coplace"

    def __init__(
        self,
        slots_per_node: int = 8,
        topology: Optional[Topology] = None,
        backend: Any = None,
        contention_aware: bool = False,
        net: NetConstants = DEFAULT_NET,
    ):
        self.slots_per_node = slots_per_node
        self.topology = (
            topology if topology is not None and not topology.is_flat
            else None
        )
        self.backend = backend
        self.contention_aware = contention_aware
        self.net = net

    # -- tier-aware zone assignment ---------------------------------------
    def _edge_medium(self, e: Edge) -> Optional[str]:
        """The medium this edge will (likely) ride, or None when unknowable
        at plan time (policies resolve per object at run time)."""
        route = e.route
        if route == "default":
            route = self.backend
        return route if isinstance(route, str) else None

    def _edge_bytes(self, dag: WorkflowDAG, e: Edge) -> int:
        """Total bytes consumers pull over this edge (the egress exposure)."""
        if e.fanout == "broadcast":
            pulls = 1 if e.dst == dag.entry.name else dag.by_name[e.dst].fan
            return pulls * e.n_objects * e.nbytes
        producers = 1 if e.src is None else dag.by_name[e.src].fan
        return producers * e.n_objects * e.nbytes

    def _tier_cost(self, level: int, nbytes: int) -> Tuple[float, float]:
        """(egress USD, tier seconds) of moving ``nbytes`` at ``level``."""
        if level <= 1:
            return 0.0, 0.0
        net = self.net
        return (
            egress_fee_usd(level, nbytes),
            net.tier_rtt(level) + nbytes / net.tier_bw(level),
        )

    def _zone_cost(
        self,
        dag: WorkflowDAG,
        stage: str,
        zi: int,
        placed: Dict[str, int],
    ) -> Tuple[float, float]:
        """Tier bill of putting ``stage`` in zone ``zi``, summed over edges
        whose other endpoint is already placed (or is the storage service)."""
        t = self.topology
        svc = t.service_zone
        fee = 0.0
        secs = 0.0

        def leg(za: int, zb: int, nbytes: int) -> None:
            nonlocal fee, secs
            level = 1 if za == zb else t.crossing(za, zb)
            f, s = self._tier_cost(level, nbytes)
            fee += f
            secs += s

        for e in dag.edges:
            if stage not in (e.src, e.dst):
                continue
            nbytes = self._edge_bytes(dag, e)
            medium = self._edge_medium(e)
            service = medium in DURABLE_MEDIA or e.src is None
            other = e.dst if e.src == stage else e.src
            if service:
                # service-homed: each endpoint pays its own leg to/from the
                # service zone, so this stage's leg is decidable alone
                leg(zi, svc, nbytes)
            elif other is not None and other in placed:
                leg(zi, placed[other], nbytes)
        return fee, secs

    def _assign_zones(self, dag: WorkflowDAG, plan: PlacementPlan) -> Dict[str, int]:
        """Greedy zone fill: pins first (hard constraints), then unpinned
        stages in declaration order, each taking the cheapest zone against
        the partial placement.  Deterministic: lexicographic (fee, seconds)
        with ties to the lowest zone index."""
        t = self.topology
        placed: Dict[str, int] = {}
        for s in dag.stages:
            if s.name in t.pin:
                # representative zone (spread pins keep their whole list at
                # assign_stage_zones time; cost uses the first)
                placed[s.name] = t.zone_index[t.pin[s.name][0]]
        for s in dag.stages:
            if s.name in placed:
                continue
            best: Optional[Tuple[float, float, int]] = None
            for zi in range(len(t.zones)):
                fee, secs = self._zone_cost(dag, s.name, zi, placed)
                key = (fee, secs, zi)
                if best is None or key < best:
                    best = key
            placed[s.name] = best[2]
            plan.zones[s.name] = t.zones[best[2]].name
            plan.notes.append(
                f"coplace: {s.name} -> zone {t.zones[best[2]].name!r} "
                f"(egress ${best[0]:.4f}, tier {best[1]:.4f}s against "
                "placed neighbors)"
            )
        return placed

    def apply(self, dag, plan):
        zone_of: Optional[Dict[str, int]] = None
        if self.topology is not None:
            zone_of = self._assign_zones(dag, plan)
        if self.contention_aware:
            plan.contention_aware = True
            plan.notes.append(
                "coplace: contention-aware pulls enabled (shared-memory "
                "FIFO backlog vs producer-NIC compared at pull time)"
            )
        # consumer instances already packed onto each producer's node: the
        # slots bound is per NODE, so every affined consumer stage counts
        # against its producer's budget, not just the largest one
        packed: Dict[str, int] = {}
        for e in dag.edges:
            if e.src is None:
                continue                      # external input: no producer
            if e.dst == dag.entry.name:
                plan.notes.append(
                    f"coplace: {e.label!r} skipped (gather into the entry, "
                    "already placed)"
                )
                continue
            p, c = dag.by_name[e.src], dag.by_name[e.dst]
            if p.fan != 1:
                plan.notes.append(
                    f"coplace: {e.label!r} skipped (consumers pull from "
                    f"{p.fan} producers)"
                )
                continue
            if p.evictable:
                plan.notes.append(
                    f"coplace: {e.label!r} skipped (evictable producer)"
                )
                continue
            if zone_of is not None and zone_of[p.name] != zone_of[c.name]:
                tz = self.topology.zones
                plan.notes.append(
                    f"coplace: {e.label!r} refused (cross-zone: {p.name} in "
                    f"{tz[zone_of[p.name]].name!r}, {c.name} in "
                    f"{tz[zone_of[c.name]].name!r} — a consumer cannot sit "
                    "on a node in another zone)"
                )
                continue
            prev = plan.affinity.get(c.name)
            if prev is not None:
                if prev != p.name:
                    plan.notes.append(
                        f"coplace: {e.label!r} skipped ({c.name} already "
                        f"affined to {prev})"
                    )
                continue                      # same pair: already planned
            if packed.get(p.name, 0) + c.fan > self.slots_per_node:
                plan.notes.append(
                    f"coplace: {e.label!r} skipped (fan {c.fan} + "
                    f"{packed.get(p.name, 0)} already packed exceeds "
                    f"{self.slots_per_node} slots/node)"
                )
                continue
            packed[p.name] = packed.get(p.name, 0) + c.fan
            plan.affinity[c.name] = p.name
            plan.notes.append(
                f"coplace: {c.name} -> node of {p.name} ({e.label!r} pulls "
                "go instance-local when slots allow)"
            )
        return dag, plan


# ---------------------------------------------------------------------------
# Pass 3: predictive spill to durable media
# ---------------------------------------------------------------------------


class PredictiveSpill(GraphPass):
    """Spill staged edges to durable media ahead of predicted eviction.

    An object staged on an instance-resident medium dies with its producer;
    if the producer's keep-alive expires before the consumer pulls, the
    engine pays the producer-death retry (re-running the whole producer
    subtree).  This pass predicts both sides from the shared telemetry
    substrate and rewrites the edge onto a durable medium when the race
    looks lost:

    * **producer lifetime** — the keep-alive floor, shortened by the
      deployment's observed reap window
      (:meth:`DeploymentTelemetry.expected_instance_lifetime_s`,
      deliberately conservative);
    * **consumer pull delay** — the observed cold-start fraction times the
      cold-start latency, plus the structural wait for gather edges (the
      entry pulls only after every later wave's compute).

    Deployment feeds are looked up under the stage name and the engine
    binding's ``<dag>.<stage>`` registration name.  No telemetry, no feed,
    or no predicted race -> no rewrite; the pass never spills on a guess.
    """

    name = "spill"

    def __init__(
        self,
        telemetry: Optional[TelemetryHub] = None,
        keep_alive_s: float = 60.0,
        cold_start_s: float = 0.5,
        durable: str = "s3",
        safety: float = 1.0,
        fault_plan: Any = None,
    ):
        if durable not in DURABLE_MEDIA:
            raise ValueError(
                f"spill target must be durable {DURABLE_MEDIA}, got {durable!r}"
            )
        self.telemetry = telemetry
        self.keep_alive_s = keep_alive_s
        self.cold_start_s = cold_start_s
        self.durable = durable
        self.safety = safety
        #: a :class:`~repro.core.faults.FaultPlan` that *schedules* producer
        #: death: evictions in the plan are certainties, not predictions, so
        #: staged instance-resident edges spill without any telemetry feed
        self.fault_plan = fault_plan

    def _feed(self, dag: WorkflowDAG, stage_name: str):
        hub = self.telemetry
        return (
            hub.deployments.get(stage_name)
            or hub.deployments.get(f"{dag.name}.{stage_name}")
        )

    def _structural_delay_s(self, dag: WorkflowDAG, e: Edge) -> float:
        """Compute that must complete between the producer's puts and the
        consumer's pulls.  Zero for ordinary staged edges (consumers fetch
        at start-of-wave); for gather edges the entry fetches only after
        every later wave ran."""
        if e.dst != dag.entry.name:
            return 0.0
        waves = dag.orchestrated_waves()
        for i, wave in enumerate(waves):
            if any(s.name == e.src for s in wave):
                return sum(
                    max((s.compute_s for s in w), default=0.0)
                    for w in waves[i + 1:]
                )
        return 0.0

    def _predicted_pull_delay_s(self, dag: WorkflowDAG, e: Edge) -> float:
        delay = self._structural_delay_s(dag, e)
        feed = self._feed(dag, e.dst)
        if feed is not None:
            now = self.telemetry.clock()
            cold = feed.cold_starts.rate(now)
            arrivals = feed.arrival_rate(now)
            if arrivals > 0.0:
                frac = min(1.0, cold / arrivals)
            else:
                frac = 1.0 if cold > 0.0 else 0.0
            delay += frac * self.cold_start_s
        return delay

    def _predicted_lifetime_s(self, dag: WorkflowDAG, e: Edge) -> float:
        life = self.keep_alive_s
        feed = self._feed(dag, e.src)
        if feed is not None:
            life = min(
                life, feed.expected_instance_lifetime_s(self.telemetry.clock())
            )
        return life

    def apply(self, dag, plan):
        hub = self.telemetry
        storm = self.fault_plan is not None and bool(
            getattr(self.fault_plan, "has_evictions", lambda: False)()
        )
        if not storm and (hub is None or not hub.deployments):
            plan.notes.append(
                "spill: no deployment telemetry feed — skipped (spilling is "
                "never guessed from an empty window)"
            )
            return dag, plan
        new_edges: List[Edge] = []
        changed = False
        for e in dag.edges:
            if e.handoff != "staged" or e.src is None:
                new_edges.append(e)
                continue
            if isinstance(e.route, str) and e.route in DURABLE_MEDIA:
                plan.notes.append(
                    f"spill: {e.label!r} already pinned durable ({e.route})"
                )
                new_edges.append(e)
                continue
            if dag.by_name[e.src].evictable:
                plan.notes.append(
                    f"spill: {e.label!r} skipped (evictable producer already "
                    "routes durable)"
                )
                new_edges.append(e)
                continue
            if storm:
                # the fault plan *schedules* producer eviction: certainty,
                # not prediction — every surviving staged edge goes durable
                new_edges.append(dataclasses.replace(e, route=self.durable))
                plan.spilled[e.label] = self.durable
                plan.notes.append(
                    f"spill: {e.label!r} -> {self.durable} (fault plan "
                    "schedules an eviction storm: pay one storage fee, "
                    "not the producer re-run)"
                )
                changed = True
                continue
            life = self._predicted_lifetime_s(dag, e)
            pull = self._predicted_pull_delay_s(dag, e)
            if math.isfinite(life) and life < self.safety * pull:
                new_edges.append(dataclasses.replace(e, route=self.durable))
                plan.spilled[e.label] = self.durable
                plan.notes.append(
                    f"spill: {e.label!r} -> {self.durable} (predicted "
                    f"producer lifetime {life:.3f}s < predicted pull "
                    f"{pull:.3f}s: pay one storage fee, not the retry)"
                )
                changed = True
            else:
                new_edges.append(e)
        if not changed:
            return dag, plan
        return WorkflowDAG(dag.name, dag.stages, new_edges), plan


class OnlineSpill:
    """Per-run, mid-stream staged->durable spill (the *online* half of
    :class:`PredictiveSpill`).

    PredictiveSpill is a compile-time pass: it rewrites edges once, from the
    telemetry snapshot at optimize() time.  Streaming edges expose the gap —
    a producer's reap window can close *between chunks*, long after the plan
    was cut.  Both lowerings therefore consult an OnlineSpill instance per
    chunk: :meth:`medium_for` re-reads the producer deployment's live reap
    window and redirects the *remaining* chunks to durable media when the
    expected instance lifetime no longer covers the consumer's estimated
    pull completion (``eta_s``).  Already-published chunks stay where they
    landed — the object legitimately splits across media, which the chunk
    protocol's per-chunk route resolution already supports.
    """

    def __init__(
        self,
        telemetry: TelemetryHub,
        durable: str = "s3",
        keep_alive_s: float = 60.0,
        cold_start_s: float = 0.5,
        safety: float = 1.0,
        pressure_patience: int = 2,
    ):
        if durable not in DURABLE_MEDIA:
            raise ValueError(
                f"spill target must be durable {DURABLE_MEDIA}, got {durable!r}"
            )
        self.telemetry = telemetry
        self.durable = durable
        self.keep_alive_s = keep_alive_s
        self.cold_start_s = cold_start_s
        self.safety = safety
        #: consecutive zero-credit publications tolerated before a
        #: backpressured stream is spilled durable (see :meth:`on_pressure`)
        self.pressure_patience = pressure_patience
        #: (edge_label, from_medium, now, eta_s) for every redirect issued
        self.spills: List[Tuple[str, str, float, float]] = []
        #: (edge_label, from_medium, now) for every backpressure spill
        self.pressure_spills: List[Tuple[str, str, float]] = []

    def _feed(self, dag: WorkflowDAG, stage_name: str):
        hub = self.telemetry
        return (
            hub.deployments.get(stage_name)
            or hub.deployments.get(f"{dag.name}.{stage_name}")
        )

    def medium_for(
        self, dag: WorkflowDAG, edge: Edge, medium: str, now: float, eta_s: float
    ) -> str:
        """Medium the next chunk of ``edge`` should land on.

        ``medium`` is what the route resolved; ``now`` is the chunk's
        publication time and ``eta_s`` the estimated delay until the
        consumer has pulled it.  Durable media pass through untouched."""
        if medium in DURABLE_MEDIA or edge.src is None:
            return medium
        life = self.keep_alive_s
        feed = self._feed(dag, edge.src)
        if feed is not None:
            life = min(life, feed.expected_instance_lifetime_s(now))
        pull = eta_s + self.cold_start_s
        if math.isfinite(life) and life < self.safety * pull:
            self.spills.append((edge.label, medium, now, eta_s))
            return self.durable
        return medium

    def on_pressure(
        self, dag: WorkflowDAG, edge: Edge, medium: str, now: float
    ) -> str:
        """Spill target for a stream under persistent backpressure.

        Called when ``pressure_patience`` consecutive chunk publications on
        ``edge`` were delayed by an exhausted credit window: the consumer is
        structurally slower than the producer, so holding the remainder in
        instance-resident media just pins sender memory.  The remaining
        chunks go durable — durable chunks bypass the credit window because
        the store, not the sender, holds them."""
        self.pressure_spills.append((edge.label, medium, now))
        return self.durable


# ---------------------------------------------------------------------------
# Pass registry + the optimize() entry point
# ---------------------------------------------------------------------------


_PASS_REGISTRY = Registry("graph pass")


def register_pass(cls: Type[GraphPass]) -> Type[GraphPass]:
    """Register a pass class under ``cls.name`` (idempotent overwrite)."""
    return _PASS_REGISTRY.register(cls)


for _cls in (SyncChainFusion, CoPlacement, PredictiveSpill):
    register_pass(_cls)


def available_passes() -> Tuple[str, ...]:
    return tuple(_PASS_REGISTRY)


DEFAULT_PASSES: Tuple[str, ...] = ("fuse", "coplace", "spill")

PassSpec = Union[str, GraphPass]


def optimize(
    dag: WorkflowDAG,
    passes: Sequence[PassSpec] = DEFAULT_PASSES,
    telemetry: Optional[TelemetryHub] = None,
    scaling: Optional[Callable[[Stage], ScalingPolicy]] = None,
    fault_plan: Any = None,
    topology: Optional[Topology] = None,
    backend: Any = None,
) -> Tuple[WorkflowDAG, PlacementPlan]:
    """Run ``passes`` in order; returns (optimized DAG, placement plan).

    Pass specs are registered names or :class:`GraphPass` instances;
    ``telemetry`` is handed to a by-name ``"spill"`` pass, ``scaling``
    (the per-stage policy factory you would bind with) to a by-name
    ``"fuse"`` pass, and ``topology`` / ``backend`` (the edge-cloud
    continuum and the run's intended default route) to a by-name
    ``"coplace"`` pass, which then emits tier-aware ``plan.zones``.  The
    input DAG is never mutated.
    """
    plan = PlacementPlan()
    for spec in passes:
        if isinstance(spec, GraphPass):
            p = spec
        else:
            cls = _PASS_REGISTRY.get(spec)
            if cls is None:
                raise ValueError(
                    f"pass must be one of {available_passes()} or a "
                    f"GraphPass instance, got {spec!r}"
                )
            # the stock passes get the convenience kwargs; a class a user
            # registered over the same name wins and constructs bare
            if cls is SyncChainFusion:
                p = SyncChainFusion(scaling=scaling)
            elif cls is PredictiveSpill:
                p = PredictiveSpill(telemetry=telemetry, fault_plan=fault_plan)
            elif cls is CoPlacement:
                p = CoPlacement(topology=topology, backend=backend)
            else:
                p = cls()
        dag, plan = p.apply(dag, plan)
    return dag, plan


__all__ = [
    "CoPlacement",
    "DEFAULT_PASSES",
    "DURABLE_MEDIA",
    "GraphPass",
    "OnlineSpill",
    "PlacementPlan",
    "PredictiveSpill",
    "SyncChainFusion",
    "available_passes",
    "optimize",
    "register_pass",
]
