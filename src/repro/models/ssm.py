"""State-space blocks: Mamba-1 (S6 selective scan) and Mamba-2 (SSD).

Both are written in the *chunked* form that the TPU kernel
(:mod:`repro.kernels.mamba_scan`) mirrors: an outer ``lax.scan`` over
sequence chunks carrying the SSM state, with the intra-chunk work done
either by an associative scan (Mamba-1: diagonal A, state (d_inner, d_state))
or by the quadratic-in-chunk matmul form (Mamba-2 / SSD: scalar-per-head
decay, which maps onto the MXU).

Decode is the O(1) single-step recurrence over carried (conv_state,
ssm_state) — the reason SSM/hybrid archs are the ones that run the
``long_500k`` cell.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig


def _causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array]) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, C); w: (W, C) -> (B, S, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W is 4: unrolled taps beat a conv op at this width
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    if b is not None:
        out = out + b
    return out


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b) -> Tuple[jax.Array, jax.Array]:
    """Single-token conv.  x_t: (B, C); conv_state: (B, W-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    if b is not None:
        out = out + b
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-1 (S6): diagonal A, per-channel state (d_inner, d_state)
# ---------------------------------------------------------------------------


def _s6_chunk(h0, a, b_in):
    """Associative scan within a chunk.

    h_t = a_t * h_{t-1} + b_t, carried h0.  a/b: (B, c, d_in, ds) f32.
    Returns (h_last, h_all)."""
    b0 = b_in.at[:, 0].add(a[:, 0] * h0)
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h_all = lax.associative_scan(comb, (a, b0), axis=1)
    return h_all[:, -1], h_all


def mamba1_mix(
    x_in: jax.Array,              # (B, S, d_in) post-conv, post-silu
    dt: jax.Array,                # (B, S, d_in) post-softplus
    B_ssm: jax.Array,             # (B, S, ds)
    C_ssm: jax.Array,             # (B, S, ds)
    A: jax.Array,                 # (d_in, ds)  (negative)
    D: jax.Array,                 # (d_in,)
    h0: Optional[jax.Array] = None,
    chunk: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Selective scan.  Returns (y (B,S,d_in), h_last (B,d_in,ds))."""
    Bsz, S, d_in = x_in.shape
    ds = B_ssm.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((Bsz, d_in, ds), f32)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk

    def per_chunk(h, args):
        xc, dtc, Bc, Cc = args  # (B, c, ...)
        a = jnp.exp(dtc.astype(f32)[..., None] * A)                 # (B,c,d_in,ds)
        b = (dtc * xc).astype(f32)[..., None] * Bc.astype(f32)[:, :, None, :]
        h_last, h_all = _s6_chunk(h, a, b)
        y = jnp.einsum("bcds,bcs->bcd", h_all, Cc.astype(f32))
        return h_last, y

    def split(t):
        return t.reshape(Bsz, n, chunk, *t.shape[2:]).swapaxes(0, 1)

    h_last, ys = lax.scan(
        per_chunk, h0, (split(x_in), split(dt), split(B_ssm), split(C_ssm))
    )
    y = ys.swapaxes(0, 1).reshape(Bsz, S, d_in).astype(x_in.dtype)
    y = y + x_in * D
    return y, h_last


def mamba1_block(
    x: jax.Array,                 # (B, S, D)
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full Mamba-1 block.  ``state`` (decode): {"conv": (B,W-1,d_in),
    "ssm": (B,d_in,ds)}.  Returns (out, new_state)."""
    s = cfg.ssm
    dtr = s.dt_rank or -(-cfg.d_model // 16)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_part, z = jnp.split(xz, 2, axis=-1)

    decode = state is not None and x.shape[1] == 1
    if decode:
        conv_out, new_conv = _conv_step(x_part[:, 0], state["conv"], p["conv_w"], p.get("conv_b"))
        x_conv = jax.nn.silu(conv_out)[:, None]
    else:
        x_conv = jax.nn.silu(_causal_conv1d(x_part, p["conv_w"], p.get("conv_b")))
        new_conv = x_part[:, -(s.conv_width - 1):, :] if x.shape[1] >= s.conv_width - 1 else None

    xdb = jnp.einsum("bse,ef->bsf", x_conv, p["x_proj"])
    dt_raw, B_ssm, C_ssm = jnp.split(xdb, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsf,fe->bse", dt_raw, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        h0 = state["ssm"]
        a = jnp.exp(dt[:, 0].astype(jnp.float32)[..., None] * A)
        b = (dt[:, 0] * x_conv[:, 0]).astype(jnp.float32)[..., None] * B_ssm[:, 0].astype(jnp.float32)[:, None, :]
        h = a * h0 + b
        y = jnp.einsum("bds,bs->bd", h, C_ssm[:, 0].astype(jnp.float32)).astype(x.dtype)
        y = (y + x_conv[:, 0] * p["D"])[:, None]
        new_state = {"conv": new_conv, "ssm": h}
    else:
        h0 = state["ssm"] if state is not None else None
        y, h_last = mamba1_mix(x_conv, dt, B_ssm, C_ssm, A, p["D"], h0, s.chunk)
        new_state = {"conv": new_conv, "ssm": h_last}

    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba-2 (SSD): scalar-per-head decay, quadratic-in-chunk matmul form
# ---------------------------------------------------------------------------


def ssd_mix(
    x_h: jax.Array,               # (B, S, H, hd)
    dt: jax.Array,                # (B, S, H) post-softplus
    B_ssm: jax.Array,             # (B, S, ds)  (single group)
    C_ssm: jax.Array,             # (B, S, ds)
    A_log: jax.Array,             # (H,)
    D: jax.Array,                 # (H,)
    h0: Optional[jax.Array] = None,
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD in chunked matmul form.  Returns (y, h_last (B,H,hd,ds))."""
    Bsz, S, H, hd = x_h.shape
    ds = B_ssm.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, hd, ds), f32)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk

    A = -jnp.exp(A_log.astype(f32))  # (H,) negative decay rates

    def per_chunk(h, args):
        xc, dtc, Bc, Cc = args                      # (B,c,...)
        la = dtc.astype(f32) * A                     # (B,c,H) log-decay
        cum = jnp.cumsum(la, axis=1)                 # (B,c,H)
        # intra-chunk: y_t = sum_{s<=t} C_t.B_s * exp(cum_t - cum_s) * dt_s x_s
        G = jnp.einsum("btn,bsn->bts", Cc.astype(f32), Bc.astype(f32))
        L = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,s,H)
        causal = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        M = jnp.where(causal[None, :, :, None], G[..., None] * L, 0.0)
        xdt = xc.astype(f32) * dtc.astype(f32)[..., None]     # (B,c,H,hd)
        y = jnp.einsum("btsh,bshd->bthd", M, xdt)
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("btn,bhdn,bth->bthd", Cc.astype(f32), h, jnp.exp(cum))
        # new carried state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)          # (B,c,H)
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bsn,bshd,bsh->bhdn", Bc.astype(f32), xdt, decay_to_end
        )
        return h_new, y

    def split(t):
        return t.reshape(Bsz, n, chunk, *t.shape[2:]).swapaxes(0, 1)

    h_last, ys = lax.scan(per_chunk, h0, (split(x_h), split(dt), split(B_ssm), split(C_ssm)))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, hd).astype(x_h.dtype)
    y = y + x_h * D[None, None, :, None]
    return y, h_last


def mamba2_block(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mamba-2 block.  in_proj emits [z, x, B, C, dt]; conv over (x,B,C)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    ds = s.d_state
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * ds], axis=-1)

    decode = state is not None and x.shape[1] == 1
    if decode:
        conv_out, new_conv = _conv_step(xbc[:, 0], state["conv"], p["conv_w"], p.get("conv_b"))
        xbc_c = jax.nn.silu(conv_out)[:, None]
    else:
        xbc_c = jax.nn.silu(_causal_conv1d(xbc, p["conv_w"], p.get("conv_b")))
        new_conv = xbc[:, -(s.conv_width - 1):, :] if x.shape[1] >= s.conv_width - 1 else None

    x_part, B_ssm, C_ssm = jnp.split(xbc_c, [d_in, d_in + ds], axis=-1)
    x_h = x_part.reshape(*x_part.shape[:2], H, s.head_dim)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])      # (B,S,H)

    if decode:
        f32 = jnp.float32
        h0 = state["ssm"]                            # (B,H,hd,ds)
        la = dt[:, 0].astype(f32) * (-jnp.exp(p["A_log"].astype(f32)))
        a = jnp.exp(la)                              # (B,H)
        xdt = x_h[:, 0].astype(f32) * dt[:, 0].astype(f32)[..., None]
        h = h0 * a[:, :, None, None] + jnp.einsum("bn,bhd->bhdn", B_ssm[:, 0].astype(f32), xdt)
        y = jnp.einsum("bn,bhdn->bhd", C_ssm[:, 0].astype(f32), h).astype(x.dtype)
        y = (y + x_h[:, 0] * p["D"][None, :, None])[:, None]
        new_state = {"conv": new_conv, "ssm": h}
        y = y.reshape(x.shape[0], 1, d_in)
    else:
        h0 = state["ssm"] if state is not None else None
        y, h_last = ssd_mix(x_h, dt, B_ssm, C_ssm, p["A_log"], p["D"], h0, s.chunk)
        new_state = {"conv": new_conv, "ssm": h_last}
        y = y.reshape(x.shape[0], x.shape[1], d_in)

    from .layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_state


# ---------------------------------------------------------------------------
# parameter shapes
# ---------------------------------------------------------------------------


def ssm_param_shapes(cfg: ModelConfig):
    s = cfg.ssm
    D = cfg.d_model
    d_in = s.expand * D
    if s.version == 1:
        dtr = s.dt_rank or -(-D // 16)
        return {
            "in_proj": ((D, 2 * d_in), ("embed", "ssm_inner")),
            "conv_w": ((s.conv_width, d_in), ("conv", "ssm_inner")),
            "conv_b": ((d_in,), ("ssm_inner",)),
            "x_proj": ((d_in, dtr + 2 * s.d_state), ("ssm_inner", None)),
            "dt_proj": ((dtr, d_in), (None, "ssm_inner")),
            "dt_bias": ((d_in,), ("ssm_inner",)),
            "A_log": ((d_in, s.d_state), ("ssm_inner", "ssm_state")),
            "D": ((d_in,), ("ssm_inner",)),
            "out_proj": ((d_in, D), ("ssm_inner", "embed")),
        }
    H = d_in // s.head_dim
    return {
        "in_proj": ((D, 2 * d_in + 2 * s.d_state + H), ("embed", None)),
        "conv_w": ((s.conv_width, d_in + 2 * s.d_state), ("conv", None)),
        "conv_b": ((d_in + 2 * s.d_state,), (None,)),
        "dt_bias": ((H,), ("ssm_heads",)),
        "A_log": ((H,), ("ssm_heads",)),
        "D": ((H,), ("ssm_heads",)),
        "norm": ((d_in,), ("ssm_inner",)),
        "out_proj": ((d_in, D), ("ssm_inner", "embed")),
    }


def ssm_state_shapes(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    if s.version == 1:
        return {
            "conv": ((batch, s.conv_width - 1, d_in), ("batch", None, "ssm_inner")),
            "ssm": ((batch, d_in, s.d_state), ("batch", "ssm_inner", "ssm_state")),
        }
    H = d_in // s.head_dim
    return {
        "conv": ((batch, s.conv_width - 1, d_in + 2 * s.d_state), ("batch", None, None)),
        "ssm": ((batch, H, s.head_dim, s.d_state), ("batch", "ssm_heads", None, None)),
    }
