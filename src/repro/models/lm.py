"""Model assembly: parameters, forward/loss, prefill and decode steps for
every assigned architecture family (dense / moe / ssm / hybrid / encoder /
vlm).

Design notes
------------
* **Scan over layers.**  All per-layer parameters are stacked with a leading
  ``n_layers`` dim and the forward is a single ``lax.scan`` (hybrid archs:
  grouped scans around the shared attention block), keeping HLO size — and
  hence dry-run compile time — O(1) in depth.
* **Remat.**  The layer body is wrapped in ``jax.checkpoint`` (policy
  selectable) so 4k-sequence training fits HBM at batch 16/device.
* **Sharding.**  Tensors are annotated through
  :class:`repro.distributed.sharding.ShardingRules`; activations are
  constrained after embedding and between blocks.  Attention picks its plan
  (head-TP vs context-parallel) from mesh divisibility — see
  :mod:`repro.models.layers`.
* **Caches.**  Decode state is a pytree: attention archs carry
  ``{"k","v"}`` of shape (L, B, T, KV, hd) with T sequence-sharded over the
  model axis (flash-decoding layout); SSM archs carry (conv, ssm) states;
  hybrids carry both.  The KV cache is THE ephemeral object the XDT serving
  path hands between prefill and decode pods.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

from ..distributed.sharding import ShardingRules, rules_for
from .config import ModelConfig
from .layers import (
    AttnPlan,
    attention_layer,
    attn_param_shapes,
    decode_attention_layer,
    mlp_param_shapes,
    plan_attention,
    rms_norm,
    swiglu,
)
from .moe import moe_layer, moe_param_shapes
from .ssm import mamba1_block, mamba2_block, ssm_param_shapes, ssm_state_shapes

PyTree = Any


# ---------------------------------------------------------------------------
# parameter inventory
# ---------------------------------------------------------------------------


def _stack(shapes: Dict[str, Tuple[Tuple[int, ...], Tuple]], n: int):
    return {
        k: ((n,) + shape, ("layers",) + tuple(axes))
        for k, (shape, axes) in shapes.items()
    }


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    """Nested pytree of (shape, logical_axes) describing all parameters."""
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    out: Dict[str, Any] = {
        "embed": ((V, D), ("vocab", "embed")),
        "final_norm": ((D,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ((D, V), ("embed", "vocab"))

    if cfg.family in ("dense", "encoder", "vlm"):
        out["blocks"] = {
            "attn": _stack(attn_param_shapes(cfg), L),
            "mlp": _stack(mlp_param_shapes(cfg), L),
            "ln1": ((L, D), ("layers", "embed")),
            "ln2": ((L, D), ("layers", "embed")),
        }
    elif cfg.family == "moe":
        out["blocks"] = {
            "attn": _stack(attn_param_shapes(cfg), L),
            "moe": _stack(moe_param_shapes(cfg), L),
            "ln1": ((L, D), ("layers", "embed")),
            "ln2": ((L, D), ("layers", "embed")),
        }
    elif cfg.family == "ssm":
        out["blocks"] = {
            "ssm": _stack(ssm_param_shapes(cfg), L),
            "ln": ((L, D), ("layers", "embed")),
        }
    elif cfg.family == "hybrid":
        h = cfg.hybrid
        out["blocks"] = {
            "ssm": _stack(ssm_param_shapes(cfg), L),
            "ln": ((L, D), ("layers", "embed")),
        }
        shared_attn = attn_param_shapes(
            cfg, n_heads=h.shared_n_heads, n_kv=h.shared_n_kv_heads
        )
        out["shared"] = {
            "attn": shared_attn,
            "mlp": mlp_param_shapes(cfg, d_ff=h.shared_d_ff),
            "ln1": ((D,), ("embed",)),
            "ln2": ((D,), ("embed",)),
        }
    else:
        raise ValueError(cfg.family)
    return out


def _leaf_is_spec(x) -> bool:
    return (
        isinstance(x, tuple)
        and len(x) == 2
        and isinstance(x[0], tuple)
        and all(isinstance(d, int) for d in x[0])
    )


def abstract_params(cfg: ModelConfig, mesh: Optional[Mesh]) -> PyTree:
    """ShapeDtypeStruct pytree with resolved shardings (dry-run stand-in)."""
    rules = rules_for(cfg, mesh) if mesh is not None else None
    dt = cfg.compute_dtype

    def mk(spec):
        shape, axes = spec
        if rules is None:
            return jax.ShapeDtypeStruct(shape, dt)
        return jax.ShapeDtypeStruct(shape, dt, sharding=rules.named(axes, shape))

    return jax.tree.map(mk, param_shapes(cfg), is_leaf=_leaf_is_spec)


def init_params(cfg: ModelConfig, key: jax.Array, mesh: Optional[Mesh] = None) -> PyTree:
    """Real parameter init (smoke tests / examples — small configs only)."""
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=_leaf_is_spec)
    keys = jax.random.split(key, len(leaves))
    rules = rules_for(cfg, mesh) if mesh is not None else None
    dt = cfg.compute_dtype

    vals = []
    for k, (shape, axes) in zip(keys, leaves):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        if len(shape) == 1 or shape[-1] == 1:
            v = jnp.ones(shape, dt) if len(shape) <= 2 else jnp.zeros(shape, dt)
        else:
            v = (jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5) * 0.5).astype(dt)
        # norms / biases / special ssm params
        vals.append(v)
    params = jax.tree.unflatten(treedef, vals)

    # fix up special leaves (norm scales = 1, A_log sensible, dt_bias small)
    def fixup(path, spec, val):
        name = path[-1] if path else ""
        shape, _ = spec
        if name in ("ln", "ln1", "ln2", "final_norm", "norm", "q_norm", "k_norm"):
            return jnp.ones(shape, dt)
        if name == "A_log":
            return jnp.log(jnp.linspace(1.0, 8.0, int(np.prod(shape)))).reshape(shape).astype(dt)
        if name == "dt_bias":
            return jnp.full(shape, -1.0, dt)
        if name == "D":
            return jnp.ones(shape, dt)
        if name in ("conv_b",):
            return jnp.zeros(shape, dt)
        return val

    def walk(sh, pr, path=()):
        if _leaf_is_spec(sh):
            return fixup(path, sh, pr)
        return {k: walk(sh[k], pr[k], path + (k,)) for k in sh}

    params = walk(shapes, params)
    if mesh is not None:
        def put(spec, val):
            _, axes = spec
            return jax.device_put(val, rules.named(axes, val.shape))
        params = jax.tree.map(put, shapes, params, is_leaf=_leaf_is_spec)
    return params


# ---------------------------------------------------------------------------
# shared forward plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelBuild:
    """Everything a step function needs beyond params+batch."""

    cfg: ModelConfig
    mesh: Optional[Mesh]
    remat: str = "full"  # "full" | "none"

    @property
    def rules(self) -> Optional[ShardingRules]:
        return rules_for(self.cfg, self.mesh) if self.mesh is not None else None

    @property
    def plan(self) -> AttnPlan:
        return plan_attention(self.cfg, self.mesh)


def _constrain(x, build: ModelBuild, axes):
    if build.mesh is None:
        return x
    return lax.with_sharding_constraint(x, build.rules.named(axes, x.shape))


def _constrain_hidden(x, build: ModelBuild):
    """Inter-block activation layout.  Default: replicated over the model
    axis (pure Megatron TP).  With ``seq_shard_acts`` (§Perf hillclimb) the
    sequence axis is sharded over the model axis between blocks — activation
    residency and HBM traffic drop by the TP width, and GSPMD converts each
    block's entry/exit psum into all-gather + reduce-scatter (same wire
    bytes, 1/TP the activation footprint)."""
    if build.cfg.seq_shard_acts:
        return _constrain(x, build, ["batch", "seq_model", None])
    return _constrain(x, build, ["batch", None, None])


def _embed(params, tokens, build: ModelBuild):
    x = params["embed"][tokens].astype(build.cfg.compute_dtype)
    return _constrain(x, build, ["batch", None, None])


def _logits(params, x, build: ModelBuild):
    cfg = build.cfg
    head = params["embed"] if cfg.tie_embeddings or "lm_head" not in params else None
    if head is not None:
        logits = jnp.einsum("bsd,vd->bsv", x, head)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return _constrain(logits, build, ["batch", None, "vocab"])


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def token_loss(params, x, labels, build: ModelBuild):
    """Mean next-token NLL from final hidden states ``x`` (B, S, D).

    With ``cfg.loss_chunk`` set (§Perf hillclimb), the (B, S, V) logits are
    never materialized: a remat'd scan walks sequence chunks, computing each
    chunk's logits + NLL and discarding them — HBM traffic for the loss head
    drops from O(S·V) tensors x several passes to O(chunk·V) working set,
    and the backward pass recomputes per-chunk under ``jax.checkpoint``.
    """
    cfg = build.cfg
    B, S, _D = x.shape
    c = cfg.loss_chunk
    if not c or S % c or S == c:
        return cross_entropy(_logits(params, x, build), labels)

    n = S // c
    xc = x.reshape(B, n, c, x.shape[-1]).swapaxes(0, 1)        # (n, B, c, D)
    lc = labels.reshape(B, n, c).swapaxes(0, 1)                # (n, B, c)

    @jax.checkpoint
    def body(acc, args):
        xi, li = args
        logits = _logits(params, xi, build)                    # (B, c, V)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return acc + (lse - gold).sum(), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc),
                        unroll=cfg.scan_unroll)
    return total / (B * S)


def _attn_mlp_block(x, bp, build: ModelBuild, *, positions=None, return_kv=False,
                    causal=None):
    cfg = build.cfg
    h, kv = attention_layer(
        rms_norm(x, bp["ln1"], cfg.rms_eps), bp["attn"], cfg, build.plan,
        build.mesh, build.rules, positions=positions, causal=causal,
        return_kv=return_kv,
    )
    x = x + h
    hn = rms_norm(x, bp["ln2"], cfg.rms_eps)
    if cfg.family == "moe" and "moe" in bp:
        m, aux = moe_layer(hn, bp["moe"], cfg, build.mesh)
    else:
        m, aux = swiglu(hn, bp["mlp"]["wi"], bp["mlp"]["wg"], bp["mlp"]["wo"]), 0.0
    x = x + m
    x = _constrain_hidden(x, build)
    return x, kv, aux


# ---------------------------------------------------------------------------
# forward passes (train / prefill)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, build: ModelBuild):
    return jax.checkpoint(fn) if build.remat == "full" else fn


def forward_transformer(params, x, build: ModelBuild, *, positions=None,
                        collect_kv=False, causal=None):
    """dense/moe/encoder/vlm backbone.  x: (B,S,D) embedded input."""
    def body(carry, bp):
        h, aux = carry
        h, kv, aux_l = _attn_mlp_block(
            h, bp, build, positions=positions, return_kv=collect_kv, causal=causal
        )
        return (h, aux + aux_l), kv

    body = _maybe_remat(body, build)
    (x, aux), kvs = lax.scan(body, (x, 0.0), params["blocks"],
                             unroll=build.cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], build.cfg.rms_eps)
    return x, aux, kvs


def forward_ssm(params, x, build: ModelBuild, *, states=None, collect_state=False):
    """ssm backbone.  states: stacked (L, ...) pytree or None."""
    cfg = build.cfg
    block = mamba1_block if cfg.ssm.version == 1 else mamba2_block

    def body(h, layer):
        bp, st = layer
        out, new_st = block(rms_norm(h, bp["ln"], cfg.rms_eps), bp["ssm"], cfg, st)
        h = _constrain_hidden(h + out, build)
        return h, (new_st if collect_state else None)

    body = _maybe_remat(body, build)
    x, new_states = lax.scan(body, x, (params["blocks"], states),
                             unroll=build.cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, new_states


def forward_hybrid(params, x, build: ModelBuild, *, positions=None,
                   collect_kv=False, states=None, collect_state=False):
    """zamba2-style: groups of mamba2 layers + one shared attention block."""
    cfg = build.cfg
    h = cfg.hybrid
    L = cfg.n_layers
    every = h.attn_every
    n_apps = L // every
    shared = params["shared"]

    def mamba_span(x, bp_span, st_span):
        def body(hc, layer):
            bp, st = layer
            out, new_st = mamba2_block(rms_norm(hc, bp["ln"], cfg.rms_eps), bp["ssm"], cfg, st)
            hc = _constrain_hidden(hc + out, build)
            return hc, (new_st if collect_state else None)
        return lax.scan(_maybe_remat(body, build), x, (bp_span, st_span),
                        unroll=build.cfg.scan_unroll)

    def shared_attn(x):
        a, kv = attention_layer(
            rms_norm(x, shared["ln1"], cfg.rms_eps), shared["attn"], cfg,
            build.plan, build.mesh, build.rules, positions=positions,
            return_kv=collect_kv,
        )
        x = x + a
        x = x + swiglu(rms_norm(x, shared["ln2"], cfg.rms_eps),
                       shared["mlp"]["wi"], shared["mlp"]["wg"], shared["mlp"]["wo"])
        return _constrain_hidden(x, build), kv

    kvs, new_states = [], []
    sl = lambda t, a, b: jax.tree.map(lambda v: v[a:b], t)
    for g in range(n_apps):
        x, kv = shared_attn(x)
        kvs.append(kv)
        span_states = None if states is None else sl(states, g * every, (g + 1) * every)
        x, st = mamba_span(x, sl(params["blocks"], g * every, (g + 1) * every), span_states)
        new_states.append(st)
    if L % every:
        span_states = None if states is None else sl(states, n_apps * every, L)
        x, st = mamba_span(x, sl(params["blocks"], n_apps * every, L), span_states)
        new_states.append(st)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    stacked_kv = None
    if collect_kv:
        ks = jnp.stack([kv[0] for kv in kvs])
        vs = jnp.stack([kv[1] for kv in kvs])
        stacked_kv = (ks, vs)
    stacked_states = (
        jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_states)
        if collect_state else None
    )
    return x, stacked_kv, stacked_states


# ---------------------------------------------------------------------------
# public step functions
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ModelConfig, mesh: Optional[Mesh], remat: str = "full",
                 aux_weight: float = 0.01):
    """Returns loss_fn(params, batch) -> scalar."""
    build = ModelBuild(cfg, mesh, remat)

    def loss_fn(params, batch):
        if cfg.family == "vlm":
            tok_x = _embed(params, batch["tokens"], build)
            x = jnp.concatenate(
                [batch["patches"].astype(cfg.compute_dtype), tok_x], axis=1
            )
            x = _constrain(x, build, ["batch", None, None])
            x, aux, _ = forward_transformer(params, x, build)
            n_img = batch["patches"].shape[1]
            return token_loss(params, x[:, n_img:], batch["labels"], build) \
                + aux_weight * aux
        if cfg.family == "encoder":
            x = batch["frames"].astype(cfg.compute_dtype)
            x = _constrain(x, build, ["batch", None, None])
            x, aux, _ = forward_transformer(params, x, build, causal=False)
            return token_loss(params, x, batch["labels"], build)
        if cfg.family == "ssm":
            x = _embed(params, batch["tokens"], build)
            x, _ = forward_ssm(params, x, build)
            return token_loss(params, x, batch["labels"], build)
        if cfg.family == "hybrid":
            x = _embed(params, batch["tokens"], build)
            x, _, _ = forward_hybrid(params, x, build)
            return token_loss(params, x, batch["labels"], build)
        # dense / moe
        x = _embed(params, batch["tokens"], build)
        x, aux, _ = forward_transformer(params, x, build)
        return token_loss(params, x, batch["labels"], build) + aux_weight * aux

    return loss_fn


def _constrain_cache(kv, build: ModelBuild):
    k, v = kv
    axes = ["layers", "batch", "kv_seq", None, None]
    return (_constrain(k, build, axes), _constrain(v, build, axes))


def make_prefill_fn(cfg: ModelConfig, mesh: Optional[Mesh], remat: str = "full",
                    pad_to: Optional[int] = None):
    """Returns prefill(params, batch) -> (last_logits (B,V), cache pytree).

    The returned cache is the XDT ephemeral object: sequence-sharded KV (and
    SSM states), ready for a decode pod to pull.  ``pad_to`` grows the KV
    sequence axis to the decode context budget.
    """
    build = ModelBuild(cfg, mesh, remat)

    def _pad_kv(kv):
        if pad_to is None:
            return kv
        k, v = kv
        extra = pad_to - k.shape[2]
        if extra <= 0:
            return kv
        pad = [(0, 0)] * k.ndim
        pad[2] = (0, extra)
        return jnp.pad(k, pad), jnp.pad(v, pad)

    def prefill(params, batch):
        cache: Dict[str, Any] = {}
        if cfg.family in ("dense", "moe", "vlm", "encoder"):
            if cfg.family == "vlm":
                tok_x = _embed(params, batch["tokens"], build)
                x = jnp.concatenate(
                    [batch["patches"].astype(cfg.compute_dtype), tok_x], axis=1
                )
            elif cfg.family == "encoder":
                x = batch["frames"].astype(cfg.compute_dtype)
            else:
                x = _embed(params, batch["tokens"], build)
            x, _, kvs = forward_transformer(
                params, x, build, collect_kv=True,
                causal=None if cfg.causal else False,
            )
            cache["k"], cache["v"] = _constrain_cache(_pad_kv(kvs), build)
        elif cfg.family == "ssm":
            x = _embed(params, batch["tokens"], build)
            S = x.shape[1]
            zero = _zero_states(cfg, x.shape[0], build)
            x, states = forward_ssm(params, x, build, states=zero, collect_state=True)
            cache.update(states)
        else:  # hybrid
            x = _embed(params, batch["tokens"], build)
            zero = _zero_states(cfg, x.shape[0], build)
            x, kvs, states = forward_hybrid(
                params, x, build, collect_kv=True, states=zero, collect_state=True
            )
            cache["k"], cache["v"] = _constrain_cache(_pad_kv(kvs), build)
            cache["conv"], cache["ssm"] = states["conv"], states["ssm"]
        B = x.shape[0]
        S = x.shape[1]
        cache["pos"] = jnp.full((B,), S, jnp.int32)
        logits = _logits(params, x[:, -1:], build)[:, 0]
        return logits, cache

    return prefill


def _zero_states(cfg: ModelConfig, batch: int, build: ModelBuild):
    shapes = ssm_state_shapes(cfg, batch)
    out = {}
    for k, (shape, axes) in shapes.items():
        full = (cfg.n_layers,) + shape
        z = jnp.zeros(full, jnp.float32 if k == "ssm" else cfg.compute_dtype)
        out[k] = _constrain(z, build, ["layers"] + list(axes))
    return out


def make_decode_fn(cfg: ModelConfig, mesh: Optional[Mesh]):
    """Returns decode(params, cache, tokens (B,1)) -> (logits (B,V), cache).

    This is ``serve_step``: one new token against the resident cache.
    """
    build = ModelBuild(cfg, mesh, remat="none")

    def decode(params, cache, tokens):
        pos = cache["pos"]  # (B,)
        x = _embed(params, tokens, build)

        if cfg.family in ("dense", "moe", "vlm"):
            def body(carry, layer):
                h = carry
                bp, ck, cv = layer
                hn = rms_norm(h, bp["ln1"], cfg.rms_eps)
                a, nk, nv = decode_attention_layer(hn, bp["attn"], cfg, ck, cv, pos)
                h = h + a
                hn = rms_norm(h, bp["ln2"], cfg.rms_eps)
                if cfg.family == "moe":
                    m, _ = moe_layer(hn, bp["moe"], cfg, build.mesh)
                else:
                    m = swiglu(hn, bp["mlp"]["wi"], bp["mlp"]["wg"], bp["mlp"]["wo"])
                return h + m, (nk, nv)

            x, (nk, nv) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]),
                                   unroll=cfg.scan_unroll)
            new_cache = dict(cache, k=nk, v=nv, pos=pos + 1)
        elif cfg.family == "ssm":
            def body(carry, layer):
                h = carry
                bp, st = layer
                out, new_st = (mamba1_block if cfg.ssm.version == 1 else mamba2_block)(
                    rms_norm(h, bp["ln"], cfg.rms_eps), bp["ssm"], cfg, st
                )
                return h + out, new_st

            states = {"conv": cache["conv"], "ssm": cache["ssm"]}
            x, new_states = lax.scan(body, x, (params["blocks"], states),
                                     unroll=cfg.scan_unroll)
            new_cache = dict(cache, pos=pos + 1, **new_states)
        else:  # hybrid
            h = cfg.hybrid
            every = h.attn_every
            n_apps = cfg.n_layers // every
            shared = params["shared"]
            sl = lambda t, a, b: jax.tree.map(lambda v: v[a:b], t)
            states = {"conv": cache["conv"], "ssm": cache["ssm"]}
            nk, nv, new_states = [], [], []

            def mamba_span(x, bp_span, st_span):
                def body(hc, layer):
                    bp, st = layer
                    out, new_st = mamba2_block(
                        rms_norm(hc, bp["ln"], cfg.rms_eps), bp["ssm"], cfg, st
                    )
                    return hc + out, new_st
                return lax.scan(body, x, (bp_span, st_span), unroll=cfg.scan_unroll)

            for g in range(n_apps):
                hn = rms_norm(x, shared["ln1"], cfg.rms_eps)
                a, k_g, v_g = decode_attention_layer(
                    hn, shared["attn"], cfg, cache["k"][g], cache["v"][g], pos
                )
                nk.append(k_g)
                nv.append(v_g)
                x = x + a
                x = x + swiglu(rms_norm(x, shared["ln2"], cfg.rms_eps),
                               shared["mlp"]["wi"], shared["mlp"]["wg"], shared["mlp"]["wo"])
                x, st = mamba_span(x, sl(params["blocks"], g * every, (g + 1) * every),
                                   sl(states, g * every, (g + 1) * every))
                new_states.append(st)
            if cfg.n_layers % every:
                x, st = mamba_span(
                    x, sl(params["blocks"], n_apps * every, cfg.n_layers),
                    sl(states, n_apps * every, cfg.n_layers))
                new_states.append(st)
            merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_states)
            new_cache = dict(
                cache, k=jnp.stack(nk), v=jnp.stack(nv), pos=pos + 1, **merged
            )

        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        logits = _logits(params, x, build)[:, 0]
        return logits, new_cache

    return decode


# ---------------------------------------------------------------------------
# cache shape inventory (dry-run stand-ins for decode cells)
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Tuple]:
    """(shape, logical_axes, dtype) per cache leaf for serve_step lowering."""
    out: Dict[str, Tuple] = {}
    dt = cfg.compute_dtype
    if cfg.family in ("dense", "moe", "vlm"):
        kv = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.hd)
        axes = ("layers", "batch", "kv_seq", None, None)
        out["k"] = (kv, axes, dt)
        out["v"] = (kv, axes, dt)
    elif cfg.family == "ssm":
        for k, (shape, axes) in ssm_state_shapes(cfg, batch).items():
            out[k] = ((cfg.n_layers,) + shape, ("layers",) + tuple(axes),
                      jnp.float32 if k == "ssm" else dt)
    else:  # hybrid
        h = cfg.hybrid
        n_apps = cfg.n_layers // h.attn_every
        kv = (n_apps, batch, seq_len, h.shared_n_kv_heads, cfg.hd)
        axes = ("layers", "batch", "kv_seq", None, None)
        out["k"] = (kv, axes, dt)
        out["v"] = (kv, axes, dt)
        for k, (shape, saxes) in ssm_state_shapes(cfg, batch).items():
            out[k] = ((cfg.n_layers,) + shape, ("layers",) + tuple(saxes),
                      jnp.float32 if k == "ssm" else dt)
    out["pos"] = ((batch,), ("batch",), jnp.int32)
    return out
