"""Mixture-of-Experts layer with XDT-patterned expert-parallel dispatch.

The MoE dispatch/combine IS the paper's scatter/gather pattern (§7.1): tokens
are scattered to expert owners chosen *after* routing (placement first, data
second), and expert outputs are gathered back.  Two dispatch modes:

``replicated_ep`` (baseline)
    Activations are replicated across the model axis (Megatron-style TP);
    each model rank owns ``E / tp`` experts and processes only the tokens
    routed to *its* experts (capacity-bounded sort-free bucketing); the
    combine folds into a single ``psum`` — the same collective the dense MLP
    TP already pays, so MoE adds **zero** extra collectives.  This mirrors
    XDT's insight: the consumer (expert shard) pulls exactly its tokens from
    the producer-resident buffer instead of pushing everything through a
    central exchange.

``dense`` (oracle)
    Every expert computed for every token, combined by routing weight.  Used
    as the numerics reference in tests (with generous capacity the EP path
    must match it exactly).

Routing: top-k over a linear router, softmax over the selected logits,
switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from .config import ModelConfig, MoEConfig


def router_topk(x_flat: jax.Array, w_router: jax.Array, k: int):
    """x_flat: (T, D) -> (weights (T,k), ids (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), w_router.astype(jnp.float32))
    top_logits, top_ids = lax.top_k(logits, k)
    weights = jax.nn.softmax(top_logits, axis=-1)
    # switch-transformer load-balance loss: E * sum(frac_tokens * frac_prob)
    E = w_router.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    frac_prob = probs.mean(axis=0)
    onehot = jax.nn.one_hot(top_ids[:, 0], E)
    frac_tok = onehot.mean(axis=0)
    aux = E * jnp.sum(frac_prob * frac_tok)
    return weights, top_ids, aux


def _expert_ffn(xs: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    """xs: (E, C, D) -> (E, C, D) per-expert SwiGLU."""
    h = jnp.einsum("ecd,edf->ecf", xs, wi) * jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xs, wg)
    )
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_dense_oracle(x: jax.Array, p: Dict[str, jax.Array], moe: MoEConfig):
    """Reference: all experts for all tokens (tests only)."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    weights, ids, aux = router_topk(xf, p["router"], moe.top_k)
    # ys: (E, T, D)
    h = jnp.einsum("td,edf->etf", xf, p["wi"]) * jax.nn.silu(
        jnp.einsum("td,edf->etf", xf, p["wg"])
    )
    ys = jnp.einsum("etf,efd->etd", h, p["wo"])
    comb = jnp.zeros_like(xf)
    for j in range(moe.top_k):
        onehot = jax.nn.one_hot(ids[:, j], p["router"].shape[-1], dtype=x.dtype)  # (T,E)
        pick = jnp.einsum("te,etd->td", onehot, ys)
        comb = comb + weights[:, j, None].astype(x.dtype) * pick
    return comb.reshape(B, S, D), aux


def _local_dispatch_ffn(
    x_flat: jax.Array,        # (T, D) tokens (replicated over model axis)
    weights: jax.Array,       # (T, k)
    ids: jax.Array,           # (T, k)
    wi: jax.Array,            # (E_loc, D, F)
    wg: jax.Array,
    wo: jax.Array,
    *,
    n_experts: int,
    capacity: int,
    rank: jax.Array,          # scalar: this shard's index on the model axis
):
    """Capacity-bounded bucketing of this rank's tokens + expert FFN.

    Token slots routed to other ranks' experts are dropped locally (they are
    served there); slots beyond capacity are dropped everywhere (standard
    switch capacity semantics).
    """
    T, k = ids.shape
    E_loc = wi.shape[0]
    flat_eid = ids.reshape(-1)                       # (T*k,)
    flat_tid = jnp.arange(T * k) // k
    flat_w = weights.reshape(-1)
    lo = rank * E_loc
    local_eid = flat_eid - lo
    is_local = (local_eid >= 0) & (local_eid < E_loc)

    # stable bucket sort by local expert id; non-local slots pushed past end
    sort_key = jnp.where(is_local, local_eid, E_loc)
    order = jnp.argsort(sort_key, stable=True)
    s_eid = sort_key[order]
    s_tid = flat_tid[order]
    s_w = flat_w[order]
    starts = jnp.searchsorted(s_eid, jnp.arange(E_loc))
    pos = jnp.arange(T * k) - starts[jnp.clip(s_eid, 0, E_loc - 1)]
    keep = (s_eid < E_loc) & (pos < capacity)

    # scatter token indices/weights into (E_loc, capacity) buffers;
    # OOB rows (dropped slots) vanish with mode="drop".
    e_idx = jnp.where(keep, s_eid, E_loc)
    p_idx = jnp.where(keep, pos, 0)
    tok_buf = jnp.zeros((E_loc, capacity), jnp.int32).at[e_idx, p_idx].set(
        s_tid.astype(jnp.int32), mode="drop"
    )
    w_buf = jnp.zeros((E_loc, capacity), x_flat.dtype).at[e_idx, p_idx].set(
        s_w.astype(x_flat.dtype), mode="drop"
    )

    xs = x_flat[tok_buf]                              # (E_loc, C, D) gather
    ys = _expert_ffn(xs, wi, wg, wo) * w_buf[..., None]
    out = jnp.zeros_like(x_flat).at[tok_buf.reshape(-1)].add(
        ys.reshape(-1, x_flat.shape[-1])
    )
    return out


def moe_layer(
    x: jax.Array,              # (B, S, D)
    p: Dict[str, jax.Array],   # router (D,E); wi/wg (E,D,F); wo (E,F,D)
    cfg: ModelConfig,
    mesh: Optional[Mesh],
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,D), aux_loss)."""
    moe = cfg.moe
    assert moe is not None
    if moe.dispatch == "dense" or mesh is None or int(mesh.shape.get("model", 1)) == 1:
        if moe.dispatch in ("replicated_ep", "a2a") and (
            mesh is None or int(mesh.shape.get("model", 1)) == 1
        ):
            # single-shard EP degenerates to rank 0 owning all experts
            return _moe_ep_single(x, p, cfg)
        return moe_dense_oracle(x, p, moe)
    if moe.dispatch == "a2a":
        return _moe_ep_a2a(x, p, cfg, mesh)
    return _moe_ep_sharded(x, p, cfg, mesh)


def _capacity(T: int, moe: MoEConfig) -> int:
    c = int(T * moe.top_k / moe.n_experts * moe.capacity_factor) + 1
    return max(moe.top_k, min(c, T * moe.top_k))


def _moe_ep_single(x, p, cfg):
    B, S, D = x.shape
    moe = cfg.moe
    xf = x.reshape(B * S, D)
    weights, ids, aux = router_topk(xf, p["router"], moe.top_k)
    out = _local_dispatch_ffn(
        xf, weights, ids, p["wi"], p["wg"], p["wo"],
        n_experts=moe.n_experts,
        capacity=_capacity(B * S, moe),
        rank=jnp.int32(0),
    )
    return out.reshape(B, S, D), aux


def _moe_ep_sharded(x, p, cfg, mesh: Mesh):
    B, S, D = x.shape
    moe = cfg.moe
    axes = tuple(mesh.shape.keys())
    batch_axes = tuple(a for a in axes if a in ("pod", "data"))
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    n_batch = 1
    for a in batch_axes:
        n_batch *= int(mesh.shape[a])
    T_loc = (B // max(1, n_batch)) * S
    cap = _capacity(T_loc, moe)

    def local(xb, router, wi, wg, wo):
        # xb: (B_loc, S, D) replicated over model; wi/wg/wo: (E_loc, D, F)
        rank = lax.axis_index("model")
        Bl = xb.shape[0]
        xf = xb.reshape(Bl * S, D)
        weights, ids, aux = router_topk(xf, router, moe.top_k)
        out = _local_dispatch_ffn(
            xf, weights, ids, wi, wg, wo,
            n_experts=moe.n_experts, capacity=cap, rank=rank,
        )
        out = lax.psum(out, "model")  # combine expert contributions (gather)
        aux = lax.pmean(aux, axes)    # replicated scalar across the mesh
        return out.reshape(Bl, S, D), aux

    xspec = P(bspec, None, None)
    wspec = P("model", None, None)
    out, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(xspec, P(None, None), wspec, wspec, wspec),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return out, aux


def _moe_ep_a2a(x, p, cfg, mesh: Mesh):
    """XDT-patterned expert parallelism: tokens move, activations don't.

    The ``replicated_ep`` baseline replicates every token's activations over
    the model axis and pays a full (T_loc, D) psum per layer — the "push
    everything through a central exchange" anti-pattern.  Here each model
    rank owns T_loc/tp tokens (sequence split); after routing, each token is
    SCATTERED (all_to_all) to the rank that owns its expert, processed
    there, and GATHERED back by a second all_to_all — exactly the paper's
    scatter/gather pattern: placement (routing) first, then each consumer
    pulls only its bytes.  Wire bytes per layer drop from 2 * T_loc * D
    (all-reduce) to 2 * k * (T_loc/tp) * D * (tp-1)/tp per rank.
    """
    B, S, D = x.shape
    moe = cfg.moe
    tp = int(mesh.shape["model"])
    axes = tuple(mesh.shape.keys())
    batch_axes = tuple(a for a in axes if a in ("pod", "data"))
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    n_batch = 1
    for a in batch_axes:
        n_batch *= int(mesh.shape[a])
    E_loc = moe.n_experts // tp
    T_own = (B // max(1, n_batch)) * (S // tp)          # tokens per model rank
    # per-destination-rank send capacity (same both directions)
    cap = max(
        moe.top_k,
        int(T_own * moe.top_k / tp * moe.capacity_factor) + 1,
    )

    def local(xb, router, wi, wg, wo):
        # xb: (B_loc, S/tp, D) — this rank's own token slice
        Bl, Sl, _ = xb.shape
        xf = xb.reshape(Bl * Sl, D)
        weights, ids, aux = router_topk(xf, router, moe.top_k)
        T, k = ids.shape

        # ---- scatter: bucket (token, k) slots by destination rank --------
        flat_eid = ids.reshape(-1)                       # (T*k,)
        flat_tid = jnp.arange(T * k) // k
        flat_w = weights.reshape(-1).astype(xf.dtype)
        dest = flat_eid // E_loc                         # destination rank
        order = jnp.argsort(dest, stable=True)
        s_dest, s_tid = dest[order], flat_tid[order]
        s_eid, s_w = flat_eid[order], flat_w[order]
        starts = jnp.searchsorted(s_dest, jnp.arange(tp))
        pos = jnp.arange(T * k) - starts[s_dest]
        keep = pos < cap                                 # capacity drop

        d_idx = jnp.where(keep, s_dest, tp)
        p_idx = jnp.where(keep, pos, 0)
        send_tok = jnp.zeros((tp, cap, D), xf.dtype).at[d_idx, p_idx].set(
            xf[s_tid], mode="drop")
        send_eid = jnp.full((tp, cap), -1, jnp.int32).at[d_idx, p_idx].set(
            (s_eid % E_loc).astype(jnp.int32), mode="drop")
        send_tid = jnp.zeros((tp, cap), jnp.int32).at[d_idx, p_idx].set(
            s_tid.astype(jnp.int32), mode="drop")
        send_w = jnp.zeros((tp, cap), xf.dtype).at[d_idx, p_idx].set(
            s_w, mode="drop")

        # ---- all_to_all #1: tokens travel to their expert's owner --------
        recv_tok = lax.all_to_all(send_tok, "model", 0, 0, tiled=False)
        recv_eid = lax.all_to_all(send_eid, "model", 0, 0, tiled=False)

        # ---- expert FFN on received tokens (one-hot per local expert) ----
        rt = recv_tok.reshape(tp * cap, D)
        re = recv_eid.reshape(tp * cap)
        onehot = (re[:, None] == jnp.arange(E_loc)[None, :])  # (tp*cap, E_loc)
        h = jnp.einsum("td,edf->etf", rt, wi) * jax.nn.silu(
            jnp.einsum("td,edf->etf", rt, wg))
        ys = jnp.einsum("etf,efd->etd", h, wo)               # (E_loc, tp*cap, D)
        out_tok = jnp.einsum("te,etd->td", onehot.astype(rt.dtype), ys)
        out_tok = out_tok.reshape(tp, cap, D)

        # ---- all_to_all #2: results travel home ---------------------------
        back = lax.all_to_all(out_tok, "model", 0, 0, tiled=False)

        # ---- combine: weighted scatter-add into this rank's tokens --------
        valid = send_eid.reshape(-1) >= 0
        contrib = back.reshape(tp * cap, D) * send_w.reshape(-1)[:, None]
        tid = jnp.where(valid, send_tid.reshape(-1), T)      # OOB -> dropped
        out = jnp.zeros_like(xf).at[tid].add(contrib, mode="drop")
        aux = lax.pmean(aux, axes)
        return out.reshape(Bl, Sl, D), aux

    xspec = P(bspec, "model", None)
    wspec = P("model", None, None)
    out, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(xspec, P(None, None), wspec, wspec, wspec),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return out, aux


def moe_param_shapes(cfg: ModelConfig):
    moe = cfg.moe
    D, E, F = cfg.d_model, moe.n_experts, moe.d_ff_expert
    return {
        "router": ((D, E), ("embed", None)),
        "wi": ((E, D, F), ("experts", "embed", "expert_ff")),
        "wg": ((E, D, F), ("experts", "embed", "expert_ff")),
        "wo": ((E, F, D), ("experts", "expert_ff", "embed")),
    }
