"""Unified architecture configuration covering all assigned model families."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # "replicated_ep": activations replicated over the model axis, experts
    #   sharded on it; combine folds into one psum (baseline).
    # "dense": every expert computed for every token (tiny-config oracle).
    dispatch: str = "replicated_ep"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    version: int = 1            # 1 = Mamba (S6), 2 = Mamba2 (SSD)
    expand: int = 2
    conv_width: int = 4
    head_dim: int = 64          # Mamba2 only
    dt_rank: Optional[int] = None  # Mamba1; default ceil(d_model/16)
    chunk: int = 256            # scan chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: shared attention block applied every ``attn_every``
    backbone layers, with one set of shared weights."""

    attn_every: int = 6
    shared_d_ff: int = 8192
    shared_n_heads: int = 32
    shared_n_kv_heads: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e4
    rms_eps: float = 1e-5
    causal: bool = True
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: Optional[str] = None      # None | "audio" | "vlm"
    frontend_seq: int = 0               # patch/frame positions in the sequence
    dtype: str = "bfloat16"
    # capability flags (drive shape-cell applicability)
    has_decode: bool = True
    subquadratic: bool = False          # can run long_500k
    attn_chunk: int = 512               # q-block for chunked attention
    scan_unroll: bool = False           # unroll layer scans (dry-run cost probes)
    # ---- performance knobs (EXPERIMENTS.md §Perf hillclimb) ----
    loss_chunk: int = 0                 # tokens/chunk for streamed CE (0 = off):
                                        # never materializes the (B,S,V) logits
    seq_shard_acts: bool = False        # Megatron-style sequence parallelism:
                                        # inter-block activations sharded over
                                        # the model axis (AG/RS replace psum)
    decode_scatter_update: bool = False # serve_step KV update via scatter
                                        # (O(B) bytes) instead of the one-hot
                                        # full-cache rewrite (O(B*T) x3)
    fsdp_params: bool = False           # shard params' d_model dim over the
                                        # data axis (ZeRO-3/FSDP via GSPMD):
                                        # per-layer weight all-gathers replace
                                        # per-layer activation psums
    # note for DESIGN §Arch-applicability when a shape cell is skipped
    skip_note: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // max(1, self.n_heads)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        per_layer = 0
        if self.family in ("dense", "moe", "encoder", "vlm"):
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            per_layer += 2 * d  # norms
            if self.qk_norm:
                per_layer += 2 * hd
        if self.family == "moe":
            m = self.moe
            per_layer += d * m.n_experts  # router
            per_layer += m.n_experts * 3 * d * m.d_ff_expert
        elif self.family in ("dense", "encoder", "vlm"):
            per_layer += 3 * d * f
        elif self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            if s.version == 1:
                dtr = s.dt_rank or -(-d // 16)
                per_layer += d * 2 * d_in               # in_proj
                per_layer += d_in * s.conv_width        # conv
                per_layer += d_in * (dtr + 2 * s.d_state) + dtr * d_in
                per_layer += d_in * s.d_state + d_in    # A, D
                per_layer += d_in * d                   # out_proj
            else:
                n_h = d_in // s.head_dim
                per_layer += d * (2 * d_in + 2 * s.d_state + n_h)  # in_proj(z,x,B,C,dt)
                per_layer += (d_in + 2 * s.d_state) * s.conv_width
                per_layer += 2 * n_h + d_in             # A, D, norm
                per_layer += d_in * d
            per_layer += d  # norm
        n += L * per_layer
        if self.family == "hybrid":
            h = self.hybrid
            shd = self.hd
            shared = (
                d * h.shared_n_heads * shd
                + 2 * d * h.shared_n_kv_heads * shd
                + h.shared_n_heads * shd * d
                + 3 * d * h.shared_d_ff
                + 2 * d
            )
            n += shared
        n += d  # final norm
        return n

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        m = self.moe
        total = self.n_params()
        all_experts = self.n_layers * m.n_experts * 3 * self.d_model * m.d_ff_expert
        active = self.n_layers * m.top_k * 3 * self.d_model * m.d_ff_expert
        return total - all_experts + active
