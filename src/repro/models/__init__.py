from .config import HybridConfig, ModelConfig, MoEConfig, SSMConfig
from .lm import (
    abstract_params,
    cache_shapes,
    init_params,
    make_decode_fn,
    make_loss_fn,
    make_prefill_fn,
    param_shapes,
)
