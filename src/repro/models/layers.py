"""Transformer layer library: RMSNorm, RoPE, GQA attention (3 sharding
modes), SwiGLU MLP.

Attention sharding modes (resolved per-arch from mesh divisibility):

* ``head``   — Megatron tensor parallelism over query heads.  When the KV
  head count does not divide the model axis, KV heads are *replicated* up to
  the TP width (``kv_repeat``), which preserves GQA math exactly (each
  expanded KV head j equals original head j // r) at the cost of r x KV
  activation memory.  Requires ``n_heads % tp == 0``.
* ``seq``    — context parallelism: query positions sharded over the model
  axis inside a ``shard_map``, K/V replicated across it.  Used when heads do
  not divide the mesh (smollm's 15 heads, llama4-scout's 40 on a 16-way
  axis).
* ``decode`` — flash-decoding layout: KV cache sequence-sharded over the
  model axis, all heads local, masked softmax over the sharded axis (GSPMD
  inserts the small max/sum combines).

All attention paths share one numerics contract and are cross-checked in
tests; the Pallas kernels in :mod:`repro.kernels` implement the TPU hot
loops for the same math.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..distributed.sharding import ShardingRules
from .config import ModelConfig


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., S) int32 -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast batch
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, half)
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    out1 = x1 * cos_ - x2 * sin_
    out2 = x2 * cos_ + x1 * sin_
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, wi) * jax.nn.silu(
        jnp.einsum("bsd,df->bsf", x, wg)
    )
    return jnp.einsum("bsf,fd->bsd", h, wo)


# ---------------------------------------------------------------------------
# attention planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnPlan:
    mode: str           # "head" | "seq"
    tp: int             # size of the model axis
    kv_repeat: int      # KV replication factor in head mode
    n_heads: int
    n_kv: int           # post-expansion KV head count (head mode)

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv


def plan_attention(cfg: ModelConfig, mesh: Optional[Mesh]) -> AttnPlan:
    tp = int(mesh.shape.get("model", 1)) if mesh is not None else 1
    H, KV = cfg.n_heads, cfg.n_kv_heads
    if H % tp == 0:
        if KV % tp == 0:
            return AttnPlan("head", tp, 1, H, KV)
        r = tp // KV if tp % KV == 0 else 0
        if r and (H // KV) % r == 0:
            return AttnPlan("head", tp, r, H, KV * r)
    return AttnPlan("seq", tp, 1, H, KV)


def _causal_mask(q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
    """(..., Sq, Sk) bool: True where k may attend (k_pos <= q_pos)."""
    return k_pos[None, :] <= q_pos[:, None]


def _sdpa(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,            # (B, Sk, KV, hd)
    mask: Optional[jax.Array],  # (Sq, Sk) or (B, 1, Sq, Sk) bool
    scale: float,
) -> jax.Array:
    """Grouped scaled-dot-product attention; f32 softmax accumulation."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        m = mask if mask.ndim == 4 else mask[None, None]
        # scores: (B, KV, G, Sq, Sk); mask broadcast over KV,G
        scores = jnp.where(m[:, :, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: Any = 0,
    chunk: int = 512,
    scale: Optional[float] = None,
) -> jax.Array:
    """Scan over query chunks against full K/V (memory O(chunk * Sk)).

    ``q_offset`` is the absolute position of q[0] (supports seq-sharded and
    decode paths); may be a traced scalar.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = (hd ** -0.5) if scale is None else scale
    chunk = min(chunk, Sq)
    if Sq % chunk != 0:  # fall back to one block (tiny/smoke shapes)
        chunk = Sq
    n_chunks = Sq // chunk
    if n_chunks == 1:
        k_pos = jnp.arange(Sk)
        q_pos = q_offset + jnp.arange(Sq)
        mask = _causal_mask(q_pos, k_pos) if causal else None
        return _sdpa(q, k, v, mask, scale)

    qc = q.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    k_pos = jnp.arange(Sk)

    def body(carry, args):
        i, qi = args
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        mask = _causal_mask(q_pos, k_pos) if causal else None
        return carry, _sdpa(qi, k, v, mask, scale)

    _, out = lax.scan(body, None, (jnp.arange(n_chunks), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# full attention layer (projections + rope + qk-norm + sdpa + out-proj)
# ---------------------------------------------------------------------------


def _project_qkv(x, p, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    return q, k, v


def attention_layer(
    x: jax.Array,                      # (B, S, D)
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    plan: AttnPlan,
    mesh: Optional[Mesh],
    rules: Optional[ShardingRules],
    *,
    positions: Optional[jax.Array] = None,     # (S,) absolute positions
    causal: Optional[bool] = None,
    return_kv: bool = False,
):
    """Training / prefill attention.  Returns (out, (k, v) | None)."""
    B, S, D = x.shape
    causal = cfg.causal if causal is None else causal
    q, k, v = _project_qkv(x, p, cfg)
    pos = jnp.arange(S) if positions is None else positions
    cos, sin = rope_angles(pos, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kv_out = (k, v) if return_kv else None  # pre-expansion layout for cache

    if plan.mode == "head":
        if plan.kv_repeat > 1:
            k = jnp.repeat(k, plan.kv_repeat, axis=2)
            v = jnp.repeat(v, plan.kv_repeat, axis=2)
        if mesh is not None and rules is not None:
            q = lax.with_sharding_constraint(
                q, rules.named(["batch", None, "heads", None], q.shape)
            )
            k = lax.with_sharding_constraint(
                k, rules.named(["batch", None, "kv_heads", None], k.shape)
            )
            v = lax.with_sharding_constraint(
                v, rules.named(["batch", None, "kv_heads", None], v.shape)
            )
        out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    else:
        out = _seq_parallel_attention(q, k, v, cfg, mesh, causal)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, kv_out


def _seq_parallel_attention(q, k, v, cfg: ModelConfig, mesh, causal: bool):
    """Context parallelism: q sequence-sharded over 'model', K/V replicated.

    Implemented in shard_map so the q-chunk scan stays shard-local.  Falls
    back to plain chunked attention when there is no model axis.
    """
    tp = int(mesh.shape.get("model", 1)) if mesh is not None else 1
    S = q.shape[1]
    if tp == 1 or S % tp != 0 or mesh is None:
        return chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)

    def local(qb, kb, vb):
        # qb: (B_loc, S/tp, H, hd); kb/vb: (B_loc, S, KV, hd)
        rank = lax.axis_index("model")
        s_loc = qb.shape[1]
        return chunked_attention(
            qb, kb, vb, causal=causal, q_offset=rank * s_loc, chunk=cfg.attn_chunk
        )

    axes = tuple(mesh.shape.keys())
    batch_axes = tuple(a for a in axes if a in ("pod", "data"))
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    qspec = P(bspec, "model", None, None)
    kvspec = P(bspec, None, None, None)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(qspec, kvspec, kvspec),
        out_specs=qspec,
        check_vma=False,
    )(q, k, v)


# ---------------------------------------------------------------------------
# decode attention (flash-decoding layout)
# ---------------------------------------------------------------------------


def decode_attention_layer(
    x: jax.Array,                 # (B, 1, D)
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    cache_k: jax.Array,           # (B, T, KV, hd) — seq-sharded over model
    cache_v: jax.Array,
    seq_positions: jax.Array,     # (B,) current length of each sequence
):
    """One-token decode: update cache at seq_positions, attend over prefix.

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B, _, D = x.shape
    T = cache_k.shape[1]
    q, k_new, v_new = _project_qkv(x, p, cfg)
    cos, sin = rope_angles(seq_positions[:, None], cfg.hd, cfg.rope_theta)  # (B,1,half)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)

    if cfg.decode_scatter_update:
        # §Perf hillclimb: a scatter touches only the updated row — with the
        # cache donated, XLA aliases input->output and the update's HBM
        # traffic is O(B*KV*hd), not O(B*T*KV*hd) x3.  Decode then streams
        # the cache ONCE (the attention read): its memory-roofline minimum.
        b_idx = jnp.arange(B)
        cache_k = cache_k.at[b_idx, seq_positions].set(
            k_new[:, 0].astype(cache_k.dtype), mode="drop")
        cache_v = cache_v.at[b_idx, seq_positions].set(
            v_new[:, 0].astype(cache_v.dtype), mode="drop")
    else:
        # baseline: one-hot masked rewrite (full-cache read+write; the op
        # stays trivially local under any cache sharding)
        onehot = jax.nn.one_hot(seq_positions, T, dtype=cache_k.dtype)  # (B, T)
        sel = onehot[:, :, None, None]
        cache_k = cache_k * (1 - sel) + sel * k_new
        cache_v = cache_v * (1 - sel) + sel * v_new

    KV = cache_k.shape[2]
    G = cfg.n_heads // KV
    qg = q.reshape(B, KV, G, cfg.hd)  # Sq == 1 squeezed
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, cache_k).astype(jnp.float32)
    scores *= cfg.hd ** -0.5
    valid = jnp.arange(T)[None, :] <= seq_positions[:, None]  # (B, T)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, cache_v).reshape(B, 1, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsk,kd->bsd", out, p["wo"].reshape(cfg.n_heads * cfg.hd, D))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# parameter factories
# ---------------------------------------------------------------------------


def attn_param_shapes(cfg: ModelConfig, d_model: Optional[int] = None,
                      n_heads: Optional[int] = None, n_kv: Optional[int] = None,
                      ) -> Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...]]]:
    """shape + logical-axes pairs for one attention block."""
    D = d_model or cfg.d_model
    H = n_heads or cfg.n_heads
    KV = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    shapes = {
        "wq": ((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        shapes["q_norm"] = ((hd,), ("head_dim",))
        shapes["k_norm"] = ((hd,), ("head_dim",))
    return shapes


def mlp_param_shapes(cfg: ModelConfig, d_ff: Optional[int] = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": ((D, F), ("embed", "d_ff")),
        "wg": ((D, F), ("embed", "d_ff")),
        "wo": ((F, D), ("d_ff", "embed")),
    }
