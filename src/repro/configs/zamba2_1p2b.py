"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Structure: 38 Mamba2 layers; one *shared* attention+MLP block (single weight
set) applied every 6 layers (6 applications).  Runs all four cells; at
long_500k the shared-block KV is sequence-sharded over the model axis and
decode attention is O(L) per step (sub-quadratic).
"""
import dataclasses
from repro.models.config import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64, causal=True, subquadratic=True,
    ssm=SSMConfig(d_state=64, version=2, expand=2, conv_width=4, head_dim=64, chunk=128),
    hybrid=HybridConfig(attn_every=6, shared_d_ff=8192, shared_n_heads=32,
                        shared_n_kv_heads=32),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=128, head_dim=16, attn_chunk=8,
    ssm=SSMConfig(d_state=8, version=2, expand=2, conv_width=4, head_dim=16, chunk=8),
    hybrid=HybridConfig(attn_every=2, shared_d_ff=128, shared_n_heads=4,
                        shared_n_kv_heads=2),
)
