"""Assigned architecture configs (public-literature dims) + reduced smoke
variants.

Every config is selectable via ``--arch <id>`` in the launchers; ``REGISTRY``
maps id -> full ModelConfig, ``smoke_config(id)`` returns the reduced
same-family variant used by CPU tests (small layers/width, few experts, tiny
vocab).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "smollm_360m",
    "granite_8b",
    "qwen3_4b",
    "starcoder2_15b",
    "llama4_scout_17b_a16e",
    "moonshot_v1_16b_a3b",
    "falcon_mamba_7b",
    "hubert_xlarge",
    "llava_next_mistral_7b",
    "zamba2_1p2b",
]

_ALIASES = {
    "smollm-360m": "smollm_360m",
    "granite-8b": "granite_8b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-15b": "starcoder2_15b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "hubert-xlarge": "hubert_xlarge",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-1.2b": "zamba2_1p2b",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.SMOKE


def registry() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
