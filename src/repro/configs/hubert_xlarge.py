"""hubert-xlarge [audio] — encoder-only, w2v2-family backbone.
[arXiv:2106.07447; unverified]

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit targets).
The modality frontend (conv feature encoder) is a STUB: input_specs()
provides precomputed frame embeddings (B, S, d_model).
decode_32k / long_500k skipped: encoder-only, no decode step.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab=504, head_dim=80, causal=False, has_decode=False,
    frontend="audio",
    skip_note="decode_32k/long_500k skipped: encoder-only (no decode step)",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab=32, head_dim=16, attn_chunk=8,
)
