"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free.  [arXiv:2410.05355]

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16, expand=2 (d_inner 8192).
Runs all four shape cells including long_500k (state is O(1) in context).
XDT note: the decode-time ephemeral object is the (conv, ssm) state — MBs,
not GBs — so the transfer win is proportionally small (DESIGN.md §5).
"""
import dataclasses
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=65024, head_dim=64, causal=True, subquadratic=True,
    ssm=SSMConfig(d_state=16, version=1, expand=2, conv_width=4, dt_rank=256),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=48, vocab=128,
    ssm=SSMConfig(d_state=4, version=1, expand=2, conv_width=4, dt_rank=8, chunk=8),
)
