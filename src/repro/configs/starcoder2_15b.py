"""starcoder2-15b [dense] — GQA, RoPE.  [arXiv:2402.19173; hf]

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
Head-TP plan with KV replication 4->16.
long_500k skipped: pure full attention.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab=49152, head_dim=128, rope_theta=1e5,
    skip_note="long_500k skipped: full quadratic attention",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=160,
    vocab=128, head_dim=16, attn_chunk=8,
)
