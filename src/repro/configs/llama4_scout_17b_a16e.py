"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (GQA kv=8) d_ff=8192(expert) vocab=202048, 16 experts
top-1.  40 heads do not divide the 16-way model axis -> context-parallel
attention; experts shard 1-per-rank (EP==TP width).
long_500k skipped: full attention.
"""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192),
    skip_note="long_500k skipped: full quadratic attention",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, d_ff=128,
    vocab=128, head_dim=16, attn_chunk=8,
    moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=96, capacity_factor=2.0),
)
