"""moonshot-v1-16b-a3b [moe] — kimi/moonlight arch, 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]

48L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=163840, MoE 64
experts top-6.  Experts shard 4-per-rank.
long_500k skipped: full attention.
"""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, head_dim=128, rope_theta=5e4,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408),
    skip_note="long_500k skipped: full quadratic attention",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab=128, head_dim=16, attn_chunk=8,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, capacity_factor=2.0),
)
