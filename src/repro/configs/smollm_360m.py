"""smollm-360m [dense] — llama-arch small.  [hf:HuggingFaceTB/SmolLM-360M; hf]

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
15 heads do not divide the 16-way model axis -> attention runs in the
context-parallel (seq) plan; MLP/vocab stay tensor-parallel.
long_500k skipped: pure full attention (see DESIGN.md §Arch-applicability).
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, head_dim=64, rope_theta=1e4, tie_embeddings=True,
    subquadratic=False,
    skip_note="long_500k skipped: full quadratic attention",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_ff=128,
    vocab=128, head_dim=16, attn_chunk=8,
)
