"""granite-8b [dense] — llama-arch, code.  [arXiv:2405.04324; hf]

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
Head-TP plan with KV replication 8->16 on the 16-way model axis.
long_500k skipped: pure full attention.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=49152, head_dim=128, rope_theta=1e4,
    skip_note="long_500k skipped: full quadratic attention",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab=128, head_dim=16, attn_chunk=8,
)
