"""llava-next-mistral-7b [vlm] — anyres tiling, mistral-7b backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
Anyres vision frontend is a STUB: input_specs() provides pre-projected patch
embeddings (B, S_img, d_model) occupying the first S_img positions of the
sequence; the LM loss covers text positions.
long_500k skipped: full attention.
"""
import dataclasses
from repro.models.config import ModelConfig

# 1 base tile + 4 anyres tiles at 24x24 patches = 2880 -> round to 1152 image
# positions for the 4k training cell (tiles are pooled 2x2 per llava-next).
CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, head_dim=128, rope_theta=1e6,
    frontend="vlm", frontend_seq=1152,
    skip_note="long_500k skipped: full quadratic attention",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab=128, head_dim=16, attn_chunk=8, frontend_seq=8,
)
