"""The XDT data-plane hot loop: a streamed, chunked buffer pull.

On real hardware the consumer's pull of a producer-resident buffer lands in
the consumer's HBM via ICI DMA; what the *kernel* layer owns is the
"reconstruct the original request" step fused into the stream (paper §5.1.1:
the SDK re-joins control message and object before invoking the handler).
Concretely: the pulled bytes are often quantized (int8 + per-row scales, the
wire format of the compressed cross-pod path) or in the producer's compute
dtype, and the consumer needs them dequantized/cast into its own layout.

This kernel streams (block_n, D) tiles HBM->VMEM->HBM with the dequant/cast
fused into the copy, so the reconstruction costs zero extra memory passes —
Pallas double-buffers the tile fetches, which is the kernel-level analogue
of the queue-proxy overlapping the object pull with function boot (§5.1.3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pull_kernel(src_ref, scale_ref, o_ref):
    x = src_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)               # (block_n, 1)
    o_ref[...] = (x * s).astype(o_ref.dtype)


def _pull_kernel_noscale(src_ref, o_ref):
    o_ref[...] = src_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_n", "interpret"))
def xdt_pull(
    src: jax.Array,                       # (N, D) producer-resident buffer
    scale: Optional[jax.Array] = None,    # (N,) per-row dequant scale
    *,
    out_dtype=jnp.bfloat16,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Streamed pull of ``src`` with fused dequant/cast into ``out_dtype``."""
    N, Dm = src.shape
    block_n = min(block_n, N)
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)

    if scale is None:
        return pl.pallas_call(
            _pull_kernel_noscale,
            grid=grid,
            in_specs=[pl.BlockSpec((block_n, Dm), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block_n, Dm), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((N, Dm), out_dtype),
            interpret=interpret,
        )(src)

    scale2d = scale.reshape(N, 1)
    return pl.pallas_call(
        _pull_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, Dm), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, Dm), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Dm), out_dtype),
        interpret=interpret,
    )(src, scale2d)
