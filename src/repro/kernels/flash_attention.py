"""Blockwise online-softmax attention (prefill hot loop) as a Pallas kernel.

TPU mapping: the grid streams (batch, q-head, q-block, kv-block) tiles
through VMEM; the innermost kv axis iterates sequentially per q-block, so the
running max / sum / accumulator live in VMEM scratch across kv steps —
Pallas double-buffers the HBM->VMEM block fetches automatically, overlapping
the next kv tile's DMA with the current tile's MXU work.  Block shapes are
MXU-aligned (q-block x head-dim and kv-block x head-dim matmuls, multiples
of 128 in production configs).

GQA is handled in the index maps: q head ``h`` reads kv head ``h // group``
— no KV replication is materialized (the kernel-level version of the
"consumer pulls exactly its bytes" principle).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,        # (1, bq, 1, hd)
    k_ref,        # (1, bk, 1, hd)
    v_ref,        # (1, bk, 1, hd)
    o_ref,        # (1, bq, 1, hd)
    m_ref,        # scratch (bq,)
    l_ref,        # scratch (bq,)
    acc_ref,      # scratch (bq, hd)
    *,
    scale: float,
    causal: bool,
    q_offset: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    if causal:
        q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)               # fully-masked rows -> 0
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "scale", "interpret"),
)
def flash_attention(
    q: jax.Array,               # (B, Sq, H, hd)
    k: jax.Array,               # (B, Sk, KV, hd)
    v: jax.Array,               # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k

    grid = (B, H, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=float(scale), causal=causal, q_offset=int(q_offset),
        block_q=block_q, block_k=block_k, n_kv_blocks=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            # VMEM scratch carrying the online-softmax state across kv steps
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
