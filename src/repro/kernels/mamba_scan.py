"""Chunked Mamba-1 selective scan as a Pallas kernel.

The recurrence h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t is sequential in
time but embarrassingly parallel over channels; the TPU mapping therefore
tiles the *channel* axis (d_inner) over the grid and VPU lanes, and streams
*sequence chunks* through VMEM with the carried state in VMEM scratch:

  grid = (batch, d_blocks, n_chunks)   # chunk axis innermost => sequential

Within a chunk the kernel runs the recurrence with a ``fori_loop`` over the
chunk's timesteps, fully vectorized over the (block_d, d_state) tile — on
TPU each step is one fused multiply-add on the VPU while the next chunk's
(x, dt, B, C) tiles are being DMA'd in.  The f32 state never leaves VMEM
between chunks (this is exactly the XDT principle at register level: the
carried state stays producer-resident; only the streamed inputs move).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref,        # (1, chunk, bd)
    dt_ref,       # (1, chunk, bd)
    b_ref,        # (1, chunk, ds)
    c_ref,        # (1, chunk, ds)
    a_ref,        # (bd, ds)
    d_ref,        # (bd,)
    h0_ref,       # (1, bd, ds)
    y_ref,        # out (1, chunk, bd)
    h_out_ref,    # out (1, bd, ds)
    h_ref,        # scratch (bd, ds) f32: carried state
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)                      # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)
    B_in = b_ref[0].astype(jnp.float32)                   # (chunk, ds)
    C_in = c_ref[0].astype(jnp.float32)
    A = a_ref[...].astype(jnp.float32)                    # (bd, ds)
    D = d_ref[...].astype(jnp.float32)                    # (bd,)

    def step(t, carry):
        h, y = carry
        a_t = jnp.exp(dt[t][:, None] * A)                 # (bd, ds)
        b_t = (dt[t] * x[t])[:, None] * B_in[t][None, :]  # (bd, ds)
        h = a_t * h + b_t
        y_t = jnp.sum(h * C_in[t][None, :], axis=-1)      # (bd,)
        return h, jax.lax.dynamic_update_index_in_dim(y, y_t, t, 0)

    h, y = jax.lax.fori_loop(
        0, chunk, step, (h_ref[...], jnp.zeros((chunk, x.shape[1]), jnp.float32))
    )
    h_ref[...] = h
    y_ref[0] = (y + x * D[None, :]).astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        h_out_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(
    x: jax.Array,               # (B, S, d_in) post-conv/silu
    dt: jax.Array,              # (B, S, d_in) post-softplus
    B_in: jax.Array,            # (B, S, ds)
    C_in: jax.Array,            # (B, S, ds)
    A: jax.Array,               # (d_in, ds) negative
    D: jax.Array,               # (d_in,)
    h0: Optional[jax.Array] = None,    # (B, d_in, ds) f32
    *,
    chunk: int = 256,
    block_d: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,d_in) in x.dtype, h_last (B,d_in,ds) f32)."""
    Bsz, S, d_in = x.shape
    ds = B_in.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, d_in, ds), jnp.float32)
    chunk = min(chunk, S)
    block_d = min(block_d, d_in)
    assert S % chunk == 0 and d_in % block_d == 0, (S, chunk, d_in, block_d)
    n_chunks, n_d = S // chunk, d_in // block_d

    grid = (Bsz, n_d, n_chunks)   # chunk innermost: state carries in scratch
    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, id_, ic: (b, ic, id_)),
            pl.BlockSpec((1, chunk, block_d), lambda b, id_, ic: (b, ic, id_)),
            pl.BlockSpec((1, chunk, ds), lambda b, id_, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, id_, ic: (b, ic, 0)),
            pl.BlockSpec((block_d, ds), lambda b, id_, ic: (id_, 0)),
            pl.BlockSpec((block_d,), lambda b, id_, ic: (id_,)),
            pl.BlockSpec((1, block_d, ds), lambda b, id_, ic: (b, id_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, id_, ic: (b, ic, id_)),
            pl.BlockSpec((1, block_d, ds), lambda b, id_, ic: (b, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, d_in), x.dtype),
            jax.ShapeDtypeStruct((Bsz, d_in, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, B_in, C_in, A, D, h0)
    return y, h_last
