"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU the Pallas lowering runs natively; everywhere else
(this CPU container, unit tests) the same kernel body executes in interpret
mode when shapes are block-aligned, falling back to the pure-jnp oracle for
ragged shapes.  Numerics are identical across all three paths (asserted by
the sweep tests), so models can call these unconditionally.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref
from .decode_attention import decode_attention as _decode_kernel
from .flash_attention import flash_attention as _flash_kernel
from .mamba_scan import mamba_scan as _mamba_kernel
from .xdt_pull import xdt_pull as _pull_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, q_offset: int = 0, scale: Optional[float] = None,
    block_q: int = 128, block_k: int = 128,
) -> jax.Array:
    Sq, Sk = q.shape[1], k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if Sq % bq or Sk % bk or q.shape[2] % k.shape[2]:
        return _ref.flash_attention_ref(
            q, k, v, causal=causal, q_offset=q_offset, scale=scale
        )
    return _flash_kernel(
        q, k, v, causal=causal, q_offset=q_offset, scale=scale,
        block_q=bq, block_k=bk, interpret=not _on_tpu(),
    )


def decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, lengths: jax.Array,
    *, scale: Optional[float] = None, block_t: int = 512,
) -> jax.Array:
    T = k.shape[1]
    bt = min(block_t, T)
    if T % bt or q.shape[1] % k.shape[2]:
        return _ref.decode_attention_ref(q, k, v, lengths, scale=scale)
    return _decode_kernel(
        q, k, v, lengths, scale=scale, block_t=bt, interpret=not _on_tpu()
    )


def mamba_scan(
    x: jax.Array, dt: jax.Array, B_in: jax.Array, C_in: jax.Array,
    A: jax.Array, D: jax.Array, h0: Optional[jax.Array] = None,
    *, chunk: int = 256, block_d: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    S, d_in = x.shape[1], x.shape[2]
    c, bd = min(chunk, S), min(block_d, d_in)
    if S % c or d_in % bd:
        return _ref.mamba_scan_ref(x, dt, B_in, C_in, A, D, h0)
    return _mamba_kernel(
        x, dt, B_in, C_in, A, D, h0, chunk=c, block_d=bd,
        interpret=not _on_tpu(),
    )


def xdt_pull(
    src: jax.Array, scale: Optional[jax.Array] = None,
    *, out_dtype=jnp.bfloat16, block_n: int = 512,
) -> jax.Array:
    N = src.shape[0]
    bn = min(block_n, N)
    if src.ndim != 2 or N % bn:
        return _ref.xdt_pull_ref(src, scale, out_dtype=out_dtype)
    return _pull_kernel(
        src, scale, out_dtype=out_dtype, block_n=bn, interpret=not _on_tpu()
    )
