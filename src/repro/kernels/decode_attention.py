"""Single-token decode attention (flash-decoding) as a Pallas kernel.

``serve_step``'s hot loop: one query token per sequence against a 32k-512k
KV cache.  This is memory-bound (arithmetic intensity ~= 2 flops/byte), so
the kernel's job is to touch every cache byte exactly once: the grid streams
(batch, kv-head, kv-block) tiles through VMEM, computing the fused
q.K -> online-softmax -> .V pass per tile with the running (m, l, acc) state
in VMEM scratch.  All G query heads of a GQA group ride along with their
shared KV tile, so GQA directly multiplies arithmetic intensity by G.

Per-sequence lengths are prefetched to SMEM (scalar memory) and drive the
masking; fully-masked tail blocks cost one VPU pass but no MXU work.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,      # SMEM (B,) int32 lengths
    q_ref,        # (1, 1, G, hd): this kv-head's query group
    k_ref,        # (1, bt, 1, hd)
    v_ref,        # (1, bt, 1, hd)
    o_ref,        # (1, 1, G, hd)
    m_ref,        # scratch (G,)
    l_ref,        # scratch (G,)
    acc_ref,      # scratch (G, hd)
    *,
    scale: float,
    block_t: int,
    n_t_blocks: int,
):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bt, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bt)
    t_pos = it * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(t_pos <= len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(it == n_t_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_t", "interpret")
)
def decode_attention(
    q: jax.Array,               # (B, H, hd) one token per sequence
    k: jax.Array,               # (B, T, KV, hd)
    v: jax.Array,               # (B, T, KV, hd)
    lengths: jax.Array,         # (B,) int32; positions [0, len] attended
    *,
    scale: Optional[float] = None,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)
    n_t = T // block_t

    # regroup q so each kv-head's G query heads are contiguous: (B, 1, KV*G, hd)
    qg = q.reshape(B, 1, KV, G, hd).reshape(B, 1, KV * G, hd)

    grid = (B, KV, n_t)
    kernel = functools.partial(
        _decode_kernel, scale=float(scale), block_t=block_t, n_t_blocks=n_t
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, it, lens: (b, 0, h, 0)),
                pl.BlockSpec((1, block_t, 1, hd), lambda b, h, it, lens: (b, it, h, 0)),
                pl.BlockSpec((1, block_t, 1, hd), lambda b, h, it, lens: (b, it, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, it, lens: (b, 0, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1, KV * G, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, hd)
