"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` implements the kernel's numerics contract with plain jax.numpy
(f32 softmax/scan accumulation, same masking semantics) and is the
ground-truth in the shape/dtype sweep tests: kernels must ``assert_allclose``
against these in interpret mode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash attention (prefill): grouped SDPA, online-softmax contract
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q: jax.Array,               # (B, Sq, H, hd)
    k: jax.Array,               # (B, Sk, KV, hd)
    v: jax.Array,               # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        k_pos = jnp.arange(Sk)
        mask = k_pos[None, :] <= q_pos[:, None]           # (Sq, Sk)
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# decode attention: 1 query token vs long KV cache, per-sequence lengths
# ---------------------------------------------------------------------------


def decode_attention_ref(
    q: jax.Array,               # (B, H, hd)
    k: jax.Array,               # (B, T, KV, hd)
    v: jax.Array,               # (B, T, KV, hd)
    lengths: jax.Array,         # (B,) int32: positions [0, len] are valid
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, k).astype(jnp.float32) * scale
    valid = jnp.arange(T)[None, :] <= lengths[:, None]    # (B, T)
    scores = jnp.where(valid[:, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w.astype(v.dtype), v)
    return out.reshape(B, H, hd)


# ---------------------------------------------------------------------------
# mamba-1 selective scan: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t; y = C.h
# ---------------------------------------------------------------------------


def mamba_scan_ref(
    x: jax.Array,               # (B, S, d_in) post-conv/silu
    dt: jax.Array,              # (B, S, d_in) post-softplus
    B_in: jax.Array,            # (B, S, ds)
    C_in: jax.Array,            # (B, S, ds)
    A: jax.Array,               # (d_in, ds) negative
    D: jax.Array,               # (d_in,)
    h0: Optional[jax.Array] = None,   # (B, d_in, ds) f32
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,d_in) in x.dtype, h_last (B,d_in,ds) f32)."""
    Bsz, S, d_in = x.shape
    ds = B_in.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((Bsz, d_in, ds), f32)

    a = jnp.exp(dt.astype(f32)[..., None] * A)            # (B,S,d_in,ds)
    # f32 contract: inputs are upcast BEFORE any multiply (kernel-aligned)
    b = (dt.astype(f32) * x.astype(f32))[..., None] * B_in.astype(f32)[:, :, None, :]

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    y = jnp.einsum("sbdn,bsn->bsd", hs, C_in.astype(f32))
    y = y + x.astype(f32) * D
    return y.astype(x.dtype), h_last


# ---------------------------------------------------------------------------
# xdt pull: streamed copy with fused dequant/cast (the data-plane hot loop)
# ---------------------------------------------------------------------------


def xdt_pull_ref(
    src: jax.Array,             # (N, D) producer-resident buffer
    scale: Optional[jax.Array] = None,   # per-row (N,) or scalar dequant scale
    out_dtype=jnp.bfloat16,
) -> jax.Array:
    x = src.astype(jnp.float32)
    if scale is not None:
        s = scale if scale.ndim == 0 else scale[:, None]
        x = x * s
    return x.astype(out_dtype)
