"""Pallas TPU kernels for the framework's compute hot spots.

Kernels (each <name>.py is the pl.pallas_call + BlockSpec implementation,
:mod:`ops` the dispatching jit wrapper, :mod:`ref` the pure-jnp oracle):

* :mod:`flash_attention`  — prefill blockwise online-softmax attention.
* :mod:`decode_attention` — one-token GQA decode vs 32k-512k KV (flash-decoding).
* :mod:`mamba_scan`       — chunked Mamba-1 selective scan, channel-tiled.
* :mod:`xdt_pull`         — the XDT data-plane stream copy with fused
                            dequant/cast ("reconstruct the request" in-flight).
"""
from .ops import decode_attention, flash_attention, mamba_scan, xdt_pull
from . import ref

__all__ = ["decode_attention", "flash_attention", "mamba_scan", "xdt_pull", "ref"]
