"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
        --steps 100 --batch 8 --seq 64 --data 2 --model 2 [--smoke] \
        [--zero1] [--loss-chunk 512] [--seq-shard] [--grad-accum 2]

``--data/--model`` build a local mesh over the visible devices (use
``--devices N`` to force a host-device count for mesh experiments).  With
``--smoke`` the reduced same-family config is used (CPU-friendly); without
it the full assigned config is instantiated — expect accelerator-scale
memory.  Checkpoints are atomic + resumable: re-running with the same
--workdir continues from the last commit.
"""
import argparse
import dataclasses
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--pod", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (set BEFORE jax import)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--straggler-deadline", type=float, default=None)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from ..configs import get_config, smoke_config
    from ..data import ShardedLoader
    from ..data.prefetch import PrefetchingFeed
    from ..models import init_params
    from ..optim import OptConfig
    from ..train import Trainer, TrainerConfig
    from .mesh import make_host_mesh

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.loss_chunk:
        cfg = dataclasses.replace(cfg, loss_chunk=args.loss_chunk)
    if args.seq_shard:
        cfg = dataclasses.replace(cfg, seq_shard_acts=True)

    mesh = None
    if args.data * args.model * max(1, args.pod) > 1:
        mesh = make_host_mesh(data=args.data, model=args.model,
                              pod=args.pod or None)

    print(f"arch={cfg.name} ({cfg.n_params()/1e6:.1f}M params) "
          f"mesh={dict(mesh.shape) if mesh else None} steps={args.steps}")
    params = init_params(cfg, jax.random.PRNGKey(0), mesh=mesh)
    loader = ShardedLoader(cfg, global_batch=args.batch, seq_len=args.seq)
    feed = PrefetchingFeed(loader.batch_at, depth=2)

    trainer = Trainer(
        cfg, params, mesh=mesh,
        opt_cfg=OptConfig(peak_lr=args.lr, warmup_steps=max(5, args.steps // 20),
                          total_steps=args.steps, zero1=args.zero1),
        tcfg=TrainerConfig(steps=args.steps,
                           checkpoint_every=max(10, args.steps // 5),
                           log_every=max(1, args.steps // 20),
                           grad_accum=args.grad_accum, remat=args.remat,
                           straggler_deadline_s=args.straggler_deadline),
        workdir=args.workdir,
        batch_at=feed.get_batch,
    )
    try:
        out = trainer.run()
    finally:
        feed.close()
    print(f"final step {out['final_step']}  loss {out['final_loss']:.4f}  "
          f"stragglers {out['stragglers']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
