"""ShapeDtypeStruct stand-ins for every (architecture x input-shape) cell.

``input_specs(arch, shape, mesh)`` returns the exact abstract arguments the
dry-run lowers: weak-type-correct, sharded, zero-allocation.  The same specs
drive the roofline accounting.

Shape cells (assignment-fixed):

=============  ========  ============  =========================================
cell           seq_len   global_batch  lowers
=============  ========  ============  =========================================
train_4k       4,096     256           train_step (loss+grad+AdamW)
prefill_32k    32,768    32            prefill_step (fwd + cache emission)
decode_32k     32,768    128           serve_step (1 token vs 32k cache)
long_500k      524,288   1             serve_step (1 token vs 512k context)
=============  ========  ============  =========================================

Applicability: encoder-only archs skip decode cells; ``long_500k`` runs only
for sub-quadratic (SSM/hybrid) archs — skips carry the config's
``skip_note`` into the results table.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..data.pipeline import make_batch_specs
from ..distributed.sharding import ShardingRules
from ..models import abstract_params, cache_shapes
from ..models.config import ModelConfig

SHAPE_CELLS: Dict[str, Dict[str, Any]] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    kind = SHAPE_CELLS[shape]["kind"]
    if kind == "decode" and not cfg.has_decode:
        return False, cfg.skip_note or "encoder-only: no decode step"
    if shape == "long_500k" and not cfg.subquadratic:
        return False, cfg.skip_note or "full attention: long_500k skipped"
    return True, ""


def _abstract(shape, dtype, axes, mesh) -> jax.ShapeDtypeStruct:
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
    rules = ShardingRules(mesh)
    return jax.ShapeDtypeStruct(
        shape, jnp.dtype(dtype), sharding=rules.named(list(axes), shape)
    )


def batch_specs(cfg: ModelConfig, shape: str, mesh) -> Dict[str, jax.ShapeDtypeStruct]:
    cell = SHAPE_CELLS[shape]
    out = {}
    for key, (shp, dtype, axes) in make_batch_specs(cfg, cell["batch"], cell["seq"]).items():
        out[key] = _abstract(shp, dtype, axes, mesh)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: str, mesh):
    """Prefill consumes tokens/frames/patches but no labels."""
    specs = batch_specs(cfg, shape, mesh)
    specs.pop("labels", None)
    return specs


def decode_specs(cfg: ModelConfig, shape: str, mesh):
    """(cache, tokens) abstract args for serve_step."""
    cell = SHAPE_CELLS[shape]
    B, T = cell["batch"], cell["seq"]
    cache = {
        key: _abstract(shp, dtype, axes, mesh)
        for key, (shp, axes, dtype) in cache_shapes(cfg, B, T).items()
    }
    tokens = _abstract((B, 1), jnp.int32, ("batch", None), mesh)
    return cache, tokens


def opt_state_specs(params_abstract, cfg=None, mesh=None, zero1: bool = False) -> Dict[str, Any]:
    if zero1 and mesh is not None and cfg is not None:
        from ..distributed.sharding import rules_for
        from ..models import param_shapes

        rules = rules_for(cfg, mesh)
        is_spec = lambda x: (isinstance(x, tuple) and len(x) == 2
                             and isinstance(x[0], tuple))
        axes_tree = jax.tree.map(lambda s: tuple(s[1]), param_shapes(cfg),
                                 is_leaf=is_spec)
        mk = lambda ax, p: jax.ShapeDtypeStruct(
            p.shape, jnp.float32, sharding=rules.zero1_named(list(ax), p.shape)
        )
        is_axes = lambda x: isinstance(x, tuple)   # axes tuples are leaves
        return {
            "mu": jax.tree.map(mk, axes_tree, params_abstract, is_leaf=is_axes),
            "nu": jax.tree.map(mk, axes_tree, params_abstract, is_leaf=is_axes),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=getattr(p, "sharding", None))
    return {
        "mu": jax.tree.map(f32, params_abstract),
        "nu": jax.tree.map(f32, params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: str, mesh, zero1: bool = False) -> Dict[str, Any]:
    """All abstract arguments for the cell's step function."""
    kind = SHAPE_CELLS[shape]["kind"]
    params = abstract_params(cfg, mesh)
    if kind == "train":
        return {
            "params": params,
            "opt_state": opt_state_specs(params, cfg, mesh, zero1=zero1),
            "batch": batch_specs(cfg, shape, mesh),
        }
    if kind == "prefill":
        return {"params": params, "batch": prefill_batch_specs(cfg, shape, mesh)}
    cache, tokens = decode_specs(cfg, shape, mesh)
    return {"params": params, "cache": cache, "tokens": tokens}
