"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: a successful
``.lower().compile()`` on the 256-chip single-pod and 512-chip two-pod host
meshes means every sharding resolves, every collective is supported, and the
per-device memory/cost analysis is available for the roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun.json

Results are cached per cell (re-runs skip completed cells unless --force).
"""
# The VERY FIRST lines, before any other import: jax locks the device count
# on first init, and the production mesh needs 512 host devices.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs import ARCH_IDS, get_config
from ..models import make_decode_fn, make_prefill_fn
from ..optim import OptConfig
from ..train import make_train_step
from .input_specs import SHAPE_CELLS, cell_applicable, input_specs
from .mesh import make_production_mesh

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+(?:e[0-9m]+)?)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO shape string like ``bf16[16,4096]``; tuples summed."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device wire-byte estimate per collective type.

    ``compiled.as_text()`` is the post-SPMD module, so shapes are per-device.
    Convention (ring schedules): all-reduce counts 2x its payload
    (reduce-scatter + all-gather phases); the others count their output
    payload once.  Start/done pairs are deduplicated via the -start suffix.
    """
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(", line)
        if not m:
            continue
        opname = m.group(3)
        base = None
        for op in COLLECTIVE_OPS:
            if opname == op or opname == op + "-start":
                base = op
                break
        if base is None:
            continue
        if opname.endswith("-done"):
            continue
        nbytes = _shape_bytes(m.group(2))
        mult = 2 if base == "all-reduce" else 1
        out[base] += nbytes * mult
    return {k: v for k, v in out.items() if v}


def build_step(cfg, kind: str, mesh, specs):
    """Returns (callable, example_args tuple of abstract values)."""
    if kind == "train":
        step = make_train_step(cfg, mesh, OptConfig(), remat="full", donate=False)
        return step, (specs["params"], specs["opt_state"], specs["batch"])
    if kind == "prefill":
        fn = jax.jit(make_prefill_fn(cfg, mesh, remat="none"))
        return fn, (specs["params"], specs["batch"])
    fn = jax.jit(make_decode_fn(cfg, mesh))
    return fn, (specs["params"], specs["cache"], specs["tokens"])


# ---------------------------------------------------------------------------
# depth-extrapolated cost probes
# ---------------------------------------------------------------------------
#
# XLA's ``cost_analysis`` counts a ``scan`` body ONCE, not trip-count times
# (verified empirically on this jax/jaxlib), so the scanned-over-layers
# production lowering wildly undercounts FLOPs/bytes.  The probes below lower
# the SAME cell at two reduced depths with the layer scan fully unrolled,
# then extrapolate linearly in depth:
#   f(L) = f(L1) + (f(L2)-f(L1)) / (L2-L1) * (L-L1).
#
# TWO probe variants per cell, because chunking cuts both ways:
#  * FLOPs + collectives come from the UNCHUNKED probe (attention/SSM chunk
#    scans collapsed to one block) — the inner chunk scan is also a ``scan``
#    whose body XLA counts once, so leaving it chunked would undercount the
#    attention FLOPs by the trip count.
#  * BYTES come from the CHUNKED probe — collapsing the chunk scan
#    materializes the full O(S^2) score matrix, inflating HBM bytes by
#    orders of magnitude vs the real blockwise/flash implementation (whose
#    HBM traffic the chunk-preserving lowering matches: weights +
#    activations + KV streamed once).
# Probe lowerings are cost-only: their memory analysis is ignored (the real,
# chunked, remat'd lowering above is what proves the cell fits).


def _probe_cfg(cfg, n_layers: int, chunked: bool = False):
    import dataclasses as dc

    big = 1 << 30
    kw = dict(n_layers=n_layers, scan_unroll=True)
    if not chunked:
        # Attention only: collapsing the q-chunk scan recovers the full
        # quadratic FLOP count that a scanned body would undercount, without
        # changing the math.  The SSM chunk is NEVER collapsed — the SSD
        # intra-chunk term is O(chunk^2), so chunk=S would change the
        # ALGORITHM's cost (verified: it inflated zamba2 prefill collectives
        # 40x), while at the production chunk the scan-interior math is a
        # negligible slice of the (correctly counted) projection FLOPs.
        kw["attn_chunk"] = big
    return dc.replace(cfg, **kw)


def _probe_depths(cfg):
    if cfg.family == "hybrid":
        e = cfg.hybrid.attn_every
        return e, 2 * e
    return 1, 2


def run_cost_probes(cfg, kind: str, shape: str, mesh) -> Optional[Dict[str, Any]]:
    L1, L2 = _probe_depths(cfg)
    vals: Dict[Any, Any] = {}
    for chunked in (False, True):
        for L in (L1, L2):
            pcfg = _probe_cfg(cfg, L, chunked=chunked)
            specs = input_specs(pcfg, shape, mesh)
            step, args = build_step(pcfg, kind, mesh, specs)
            with mesh:
                lowered = step.lower(*args)
                compiled = lowered.compile()
                ca = compiled.cost_analysis()
                coll = (
                    parse_collective_bytes(compiled.as_text())
                    if not chunked else {}
                )
            vals[(chunked, L)] = {
                "flops": ca.get("flops", 0.0),
                "bytes": ca.get("bytes accessed", 0.0),
                "coll": coll,
            }
    L = cfg.n_layers

    def extrap(f1, f2):
        slope = (f2 - f1) / (L2 - L1)
        return f1 + slope * (L - L1)

    un1, un2 = vals[(False, L1)], vals[(False, L2)]
    ch1, ch2 = vals[(True, L1)], vals[(True, L2)]
    all_ops = set(un1["coll"]) | set(un2["coll"])
    coll = {
        op: max(0.0, extrap(un1["coll"].get(op, 0), un2["coll"].get(op, 0)))
        for op in all_ops
    }
    return {
        # FLOPs/collectives: unchunked probe (chunk scans would undercount)
        "flops_per_device": extrap(un1["flops"], un2["flops"]),
        # bytes: chunked probe (unchunked would materialize O(S^2) scores)
        "bytes_per_device": extrap(ch1["bytes"], ch2["bytes"]),
        "bytes_per_device_unchunked": extrap(un1["bytes"], un2["bytes"]),
        "collective_bytes_per_device": coll,
        "probe_depths": [L1, L2],
        "probe_raw": {f"chunked={c},L={l}": v for (c, l), v in vals.items()},
    }


def run_cell(arch: str, shape: str, multi_pod: bool) -> Dict[str, Any]:
    cfg = get_config(arch)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name}
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        kind = SHAPE_CELLS[shape]["kind"]
        specs = input_specs(cfg, shape, mesh)
        step, args = build_step(cfg, kind, mesh, specs)
        t0 = time.time()
        with mesh:
            lowered = step.lower(*args)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
            ca = compiled.cost_analysis()
            ma = compiled.memory_analysis()
            hlo = compiled.as_text()
        probes = run_cost_probes(cfg, kind, shape, mesh)
        rec.update(
            status="ok",
            kind=kind,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            # raw (scan-undercounted) numbers from the production lowering:
            flops_per_device_scanbody=ca.get("flops", 0.0),
            bytes_per_device_scanbody=ca.get("bytes accessed", 0.0),
            collective_bytes_per_device_scanbody=parse_collective_bytes(hlo),
            # depth-extrapolated HLO cost (the roofline inputs):
            flops_per_device=probes["flops_per_device"],
            bytes_per_device=probes["bytes_per_device"],
            bytes_per_device_unchunked=probes.get("bytes_per_device_unchunked"),
            collective_bytes_per_device=probes["collective_bytes_per_device"],
            probe_depths=probes["probe_depths"],
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            n_devices=len(mesh.devices.flat),
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPE_CELLS))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPE_CELLS) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if os.path.exists(args.out):
        # ALWAYS merge into the existing file; --force only re-runs the
        # selected cells (it must never discard other cells' records).
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if key in results and results[key].get("status") in ("ok", "skipped") and not args.force:
                    print(f"[cached] {key}: {results[key]['status']}")
                    continue
                print(f"[run] {key} ...", flush=True)
                rec = run_cell(arch, shape, mp)
                results[key] = rec
                line = rec["status"]
                if rec["status"] == "ok":
                    line += (
                        f" lower={rec['lower_s']}s compile={rec['compile_s']}s "
                        f"flops/dev={rec['flops_per_device']:.3e} "
                        f"peak_mem/dev={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB"
                    )
                elif rec["status"] == "failed":
                    line += " " + rec["error"][:200]
                print(f"      {line}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_fail = sum(1 for r in results.values() if r["status"] == "failed")
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
