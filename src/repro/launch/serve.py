"""Serving launcher: single-pod continuous batching or disaggregated
prefill/decode with the XDT cache handoff.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
        [--disagg --decode-pods 2 --backend xdt|staged] \
        [--requests 8 --new-tokens 8]
"""
import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--disagg", action="store_true")
    ap.add_argument("--backend", default="xdt", choices=["xdt", "staged"])
    ap.add_argument("--decode-pods", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..configs import get_config, smoke_config
    from ..models import init_params
    from ..serving import DisaggregatedServer, ServingEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        print(f"{cfg.name} is encoder-only: no decode step to serve")
        return 1
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=int(rng.integers(4, 12)))
               for _ in range(args.requests)]

    t0 = time.time()
    if args.disagg:
        srv = DisaggregatedServer(cfg, params, n_decode_pods=args.decode_pods,
                                  max_batch=args.max_batch, max_len=args.max_len,
                                  backend=args.backend)
        for p in prompts:
            srv.submit(p, max_new_tokens=args.new_tokens)
        done = srv.run_until_drained()
        rep = srv.handoff_report()
        print(f"disagg[{args.backend}]: {len(done)} requests, "
              f"{rep['handoffs']:.0f} handoffs of "
              f"{rep['avg_cache_bytes']/1024:.0f}KB caches")
    else:
        srv = ServingEngine(cfg, params, max_batch=args.max_batch,
                            max_len=args.max_len)
        for p in prompts:
            srv.submit(p, max_new_tokens=args.new_tokens)
        done = srv.run_until_drained()
        print(f"single-pod: {len(done)} requests in {srv.steps} engine steps")
    wall = time.time() - t0
    n_tok = sum(len(r.generated) for r in done.values())
    print(f"{n_tok} tokens in {wall:.1f}s ({n_tok/wall:.1f} tok/s)")
    for rid in list(done)[:4]:
        print(f"  req {rid}: {done[rid].generated}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
