"""Refresh the probe-derived fields of an existing dryrun.json in place.

Used to upgrade a recorded sweep to the v2 probe methodology without
re-compiling the (expensive) production lowerings: for non-SSM archs only
the chunked-bytes probes are re-run (FLOPs/collectives are unchanged by the
methodology fix); for SSM/hybrid archs the full probe set is re-run (the
SSM-chunk fix changes FLOPs and collectives too).

    PYTHONPATH=src python -m repro.launch.patch_probes [--out results/dryrun.json]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time

from ..configs import get_config
from .dryrun import _probe_cfg, _probe_depths, build_step, run_cost_probes
from .input_specs import input_specs
from .mesh import make_production_mesh


def chunked_bytes_probe(cfg, kind, shape, mesh) -> float:
    L1, L2 = _probe_depths(cfg)
    vals = {}
    for L in (L1, L2):
        pcfg = _probe_cfg(cfg, L, chunked=True)
        specs = input_specs(pcfg, shape, mesh)
        step, args = build_step(pcfg, kind, mesh, specs)
        with mesh:
            compiled = step.lower(*args).compile()
            vals[L] = compiled.cost_analysis().get("bytes accessed", 0.0)
    L = cfg.n_layers
    return vals[L1] + (vals[L2] - vals[L1]) / (L2 - L1) * (L - L1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    args = ap.parse_args()
    with open(args.out) as f:
        results = json.load(f)

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    # single-pod first (feeds the roofline table), cheap archs first
    keys = [k for k in sorted(results)
            if results[k].get("status") == "ok" and k.split("|")[2] in meshes]
    keys.sort(key=lambda k: (k.split("|")[2] != "single",
                             get_config(k.split("|")[0]).family in ("ssm", "hybrid")))
    for key in keys:
        rec = results[key]
        if rec.get("probe_v2"):
            continue
        arch, shape, mesh_name = key.split("|")
        cfg = get_config(arch)
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        kind = rec["kind"]
        t0 = time.time()
        try:
            if cfg.family in ("ssm", "hybrid"):
                probes = run_cost_probes(cfg, kind, shape, mesh)
                rec.update(
                    flops_per_device=probes["flops_per_device"],
                    bytes_per_device=probes["bytes_per_device"],
                    collective_bytes_per_device=probes["collective_bytes_per_device"],
                )
            else:
                rec["bytes_per_device"] = chunked_bytes_probe(cfg, kind, shape, mesh)
            rec["probe_v2"] = True
            print(f"[patched] {key} ({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:
            print(f"[FAILED]  {key}: {type(e).__name__}: {e}", flush=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    print("done")


if __name__ == "__main__":
    main()
