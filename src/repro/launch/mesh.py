"""Production mesh construction.

Single pod: 256 chips as (16 data, 16 model).  Multi-pod: 2 pods x 256 =
512 chips as (2 pod, 16 data, 16 model); the ``pod`` axis carries either
data parallelism (training: hierarchical gradient reduction) or the
prefill/decode disaggregation boundary (serving: XDT cache pulls are the
only traffic that crosses it).

Functions, not module-level constants: importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
sees 512).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 names explicit/auto axis types; older versions have neither
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:
    _AXIS_KW = lambda n: {}  # noqa: E731


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, have {len(devices)} "
            "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    return jax.make_mesh(shape, axes, devices=devices, **_AXIS_KW(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1, pod: Optional[int] = None) -> Mesh:
    """Small mesh over whatever local devices exist (tests / examples)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n], **_AXIS_KW(len(axes)))
