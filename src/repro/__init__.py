"""repro: XDT (Expedited Data Transfers) rebuilt as a JAX/TPU framework."""
__version__ = "1.0.0"
