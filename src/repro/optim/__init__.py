from .adamw import OptConfig, adamw_init, adamw_update, global_norm
from .schedule import warmup_cosine
from .compression import int8_compress, int8_decompress, compressed_psum
