"""AdamW with decoupled weight decay, global-norm clipping, bf16 params /
f32 moments (production memory layout: 2 + 8 bytes per parameter)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    # ZeRO-1 (§Perf hillclimb): shard f32 moments + the update math over the
    # data/pod axes; grads reduce-scatter instead of all-reduce, params
    # all-gather after the sharded update.  Memory: 8 bytes/param -> 8/DP.
    zero1: bool = False


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params: PyTree,
    grads: PyTree,
    state: PyTree,
    cfg: OptConfig,
    lr_schedule: Optional[Callable[[jax.Array], jax.Array]] = None,
) -> Tuple[PyTree, PyTree, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    if lr_schedule is None:
        from .schedule import warmup_cosine

        lr_schedule = warmup_cosine(cfg.peak_lr, cfg.warmup_steps, cfg.total_steps)
    lr = lr_schedule(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (update + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "mu": jax.tree.unflatten(treedef, new_mu),
            "nu": jax.tree.unflatten(treedef, new_nu),
            "step": step,
        },
        gnorm,
    )
