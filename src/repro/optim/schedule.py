"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Linear warmup then cosine decay to ``final_frac * peak_lr``."""

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / jnp.maximum(1.0, float(warmup_steps))
        t = (step - warmup_steps) / jnp.maximum(1.0, float(total_steps - warmup_steps))
        t = jnp.clip(t, 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule
