"""Gradient compression for cross-pod reduction: int8 + error feedback.

At 512+ chips the inter-pod (DCN/slow-link) gradient all-reduce is the
scaling bottleneck; 4x compression (bf16 -> int8 with per-tensor scale) cuts
the cross-pod collective term proportionally.  Error feedback keeps the
compounding quantization bias out of the training trajectory (residual from
step t is added back at t+1), the standard trick that makes low-bit
reductions convergence-safe.

``compressed_psum`` is the in-graph form used inside shard_map: quantize ->
psum(int32 accumulate) -> dequantize, with the residual returned to the
caller to carry.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def int8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(
    grad: jax.Array, residual: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Apply error feedback then quantize.  Returns (q, scale, new_residual)."""
    g = grad.astype(jnp.float32)
    if residual is not None:
        g = g + residual
    q, scale = int8_compress(g)
    new_residual = g - int8_decompress(q, scale)
    return q, scale, new_residual


def compressed_psum(
    grad: jax.Array,
    axis: str,
    residual: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """In-graph compressed all-reduce (mean) over ``axis`` (inside shard_map).

    The quantization scale is agreed FIRST (pmax of per-rank amax — an O(1)
    collective), so every rank quantizes onto the same grid and the int32
    accumulation is exact given the grid.  int8 payloads cannot overflow
    int32 below 2^24 ranks; wire bytes drop ~4x vs bf16.  Returns
    (mean grad f32, residual to carry for error feedback).
    """
    g = grad.astype(jnp.float32)
    if residual is not None:
        g = g + residual
    amax = lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_residual = g - q.astype(jnp.float32) * scale
    acc = lax.psum(q.astype(jnp.int32), axis)
    n = lax.psum(jnp.ones((), jnp.float32), axis)
    out = acc.astype(jnp.float32) * scale / n
    return out, new_residual
