"""Continuous-batching serving engine.

One decode batch of ``max_batch`` slots steps in lockstep; finished/empty
slots are refilled from the request queue by running prefill and *inserting*
the resulting KV/state cache into the slot.  That insert is exactly the
ephemeral-object handoff XDT addresses — in the single-pod engine it is a
device-local dynamic-update; in :mod:`repro.serving.disagg` it crosses pods
through the XDT transfer substrate.

Greedy decoding; per-slot lengths tracked via the cache's ``pos`` vector
(decode attention masks beyond each sequence's own length, so ragged batches
are exact, not approximate).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import cache_shapes, make_decode_fn, make_prefill_fn
from ..models.config import ModelConfig

PyTree = Any


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def empty_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    out = {}
    for key, (shape, _axes, dtype) in cache_shapes(cfg, batch, max_len).items():
        out[key] = jnp.zeros(shape, dtype)
    return out


def insert_cache(batch_cache: PyTree, single_cache: PyTree, slot: int) -> PyTree:
    """Insert a prefill cache (batch=1) into decode slot ``slot``.

    Every cache leaf has the batch axis at position 1 (leaf layout
    (L, B, ...)) except ``pos`` (B,).
    """
    def ins(dst, src):
        if dst.ndim == 1:  # pos
            return dst.at[slot].set(src[0].astype(dst.dtype))
        return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

    return jax.tree.map(ins, batch_cache, single_cache)


class ServingEngine:
    """Single-pod continuous batching."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        mesh=None,
        max_batch: int = 4,
        max_len: int = 64,
    ):
        assert cfg.has_decode, f"{cfg.name} is encoder-only"
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill = jax.jit(make_prefill_fn(cfg, mesh, remat="none", pad_to=max_len))
        self.decode = jax.jit(make_decode_fn(cfg, mesh))
        self.cache = empty_cache(cfg, max_batch, max_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.last_tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.queue: List[Request] = []
        self._ids = itertools.count()
        self.completed: Dict[int, Request] = {}
        self.steps = 0

    # -- API ----------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        req = Request(next(self._ids), np.asarray(prompt, np.int32), max_new_tokens)
        self.queue.append(req)
        return req.request_id

    def prefill_request(self, req: Request) -> Tuple[PyTree, int]:
        """Run prefill for one request; returns (cache, first_token)."""
        logits, cache = self.prefill(
            self.params, {"tokens": jnp.asarray(req.prompt)[None]}
        )
        return cache, int(jnp.argmax(logits[0]))

    def admit(self, req: Request, cache: PyTree, first_token: int, slot: int) -> None:
        self.cache = insert_cache(self.cache, cache, slot)
        self.last_tokens = self.last_tokens.at[slot, 0].set(first_token)
        req.generated.append(first_token)
        self.slots[slot] = req

    def _refill(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                cache, tok = self.prefill_request(req)
                self.admit(req, cache, tok, slot)

    def step(self) -> None:
        """One engine iteration: refill free slots, one decode step."""
        self._refill()
        if all(s is None for s in self.slots):
            return
        logits, self.cache = self.decode(self.params, self.cache, self.last_tokens)
        next_tokens = jnp.argmax(logits, axis=-1)
        self.last_tokens = next_tokens[:, None].astype(jnp.int32)
        self.steps += 1
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(next_tokens[slot]))
            if (
                len(req.generated) >= req.max_new_tokens
                or len(req.prompt) + len(req.generated) >= self.max_len - 1
            ):
                req.done = True
                self.completed[req.request_id] = req
                self.slots[slot] = None

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Request]:
        while (self.queue or any(s is not None for s in self.slots)) and self.steps < max_steps:
            self.step()
        return self.completed
