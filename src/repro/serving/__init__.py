from .engine import Request, ServingEngine
from .disagg import DisaggregatedServer
