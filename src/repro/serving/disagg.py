"""Disaggregated prefill/decode serving with XDT cache handoff.

This is the paper's architecture transplanted to LLM serving:

* the **prefill pod** is the *producer function* — it computes the KV/state
  cache (the ephemeral object; 10s of MB to GBs) and ``put``s it into its
  buffer registry, minting a secure :class:`XDTRef`;
* the **control plane** (:class:`repro.core.scheduler.ControlPlane`) picks
  the decode instance — placement first, independent of the payload —
  exactly like the activator steering an invocation;
* the **decode pod** is the *consumer* — its queue-proxy analogue ``get``s
  (pulls) the cache directly from the prefill pod's device memory and
  inserts it into a batch slot.

Backends:

``xdt``     zero-copy put, direct pull (on hardware: one ICI/DCN traversal,
            prefill-sharding -> decode-sharding).
``staged``  the through-storage baseline: the cache is staged device ->
            host object store -> device (two extra copies + service fees),
            i.e. what S3/ElastiCache-based serving does today.

Both produce bit-identical generations (asserted in tests); they differ in
modeled latency/cost, reported via ``handoff_report()``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..core.buffers import BufferRegistry
from ..core.clock import ensure_clock
from ..core.refs import XDTRef
from ..core.scheduler import ControlPlane, ScalingPolicy
from ..core.transfer import TransferEngine, modeled_transfer_seconds
from ..models.config import ModelConfig
from .engine import Request, ServingEngine

PyTree = Any


class DisaggregatedServer:
    """One prefill pod + N decode pods over the XDT substrate."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        mesh=None,
        n_decode_pods: int = 2,
        max_batch: int = 4,
        max_len: int = 64,
        backend: str = "xdt",
        clock=None,
    ):
        self.cfg = cfg
        self.backend = backend
        self.clock = ensure_clock(clock)  # virtual under a simulator harness
        engine_backend = "xdt" if backend == "xdt" else "elasticache"
        self.transfer = TransferEngine(
            engine_backend,
            producer_coords=(0,),
            registry=BufferRegistry(max_slots=64, clock=self.clock),
            clock=self.clock,
        )
        self.control = ControlPlane(clock=self.clock)
        self.control.register(
            "decode",
            ScalingPolicy(min_instances=n_decode_pods, max_instances=n_decode_pods,
                          target_concurrency=max_batch),
            placer=lambda i: (1 + i,),  # pods 1..N; pod 0 is prefill
        )
        # prefill pod: only needs the prefill fn — reuse an engine shell
        self.prefill_pod = ServingEngine(cfg, params, mesh, max_batch=1, max_len=max_len)
        self.decode_pods: List[ServingEngine] = [
            ServingEngine(cfg, params, mesh, max_batch=max_batch, max_len=max_len)
            for _ in range(n_decode_pods)
        ]
        self.pod_of_request: Dict[int, int] = {}
        self.instance_of_request: Dict[int, int] = {}
        self._released: set = set()
        self.handoffs = 0

    # ----------------------------------------------------------------- serve
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        """Prefill-side entry: compute cache, hand off to a decode pod."""
        req = Request(next(self.prefill_pod._ids), np.asarray(prompt, np.int32),
                      max_new_tokens)
        # 1. producer computes the ephemeral object
        cache, first_token = self.prefill_pod.prefill_request(req)
        # 2. producer buffers it and mints the reference (data stays put)
        ref: XDTRef = self.transfer.put(cache, n_retrievals=1)
        # 3. control plane picks the consumer instance (placement first!)
        instance, _wait = self.control.steer("decode")
        pod_idx = instance.coords[0] - 1
        # 4. consumer pulls the object directly and admits the request
        pulled = self.transfer.get(ref)
        pod = self.decode_pods[pod_idx]
        slot = pod.slots.index(None)  # scheduler guaranteed capacity
        pod.admit(req, pulled, first_token, slot)
        self.pod_of_request[req.request_id] = pod_idx
        # the slot stays "in flight" on the control plane until the request
        # completes — that is what the autoscaler's load metric measures
        self.instance_of_request[req.request_id] = instance.instance_id
        self.handoffs += 1
        return req.request_id

    def step(self) -> None:
        for pod in self.decode_pods:
            if any(s is not None for s in pod.slots):
                pod.step()
            for rid in list(pod.completed):
                if rid in self.instance_of_request and rid not in self._released:
                    self.control.release("decode", self.instance_of_request[rid])
                    self._released.add(rid)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Request]:
        done: Dict[int, Request] = {}
        steps = 0
        while steps < max_steps:
            if all(all(s is None for s in pod.slots) for pod in self.decode_pods):
                break
            self.step()
            steps += 1
        for pod in self.decode_pods:
            done.update(pod.completed)
        return done

    # ------------------------------------------------------------------ report
    def handoff_report(self) -> Dict[str, float]:
        """Modeled per-handoff latency + engine stats for this backend."""
        stats = self.transfer.stats
        nbytes = stats.bytes_moved / max(1, stats.transfers)
        return {
            "handoffs": float(self.handoffs),
            "avg_cache_bytes": nbytes,
            "modeled_latency_s_per_handoff": (
                stats.modeled_seconds / max(1, stats.transfers)
            ),
            "modeled_latency_s_if_s3": modeled_transfer_seconds("s3", int(nbytes)),
            "modeled_latency_s_if_elasticache": modeled_transfer_seconds(
                "elasticache", int(nbytes)
            ),
            "modeled_latency_s_if_xdt": modeled_transfer_seconds("xdt", int(nbytes)),
        }
