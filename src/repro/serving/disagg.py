"""Disaggregated prefill/decode serving with XDT cache handoff.

This is the paper's architecture transplanted to LLM serving:

* the **prefill pod** is the *producer function* — it computes the KV/state
  cache (the ephemeral object; 10s of MB to GBs) and ``put``s it into its
  buffer registry, minting a secure :class:`XDTRef`;
* the **control plane** picks the decode instance — placement first,
  independent of the payload — exactly like the activator steering an
  invocation;
* the **decode pod** is the *consumer* — its queue-proxy analogue ``get``s
  (pulls) the cache directly from the prefill pod's device memory and
  inserts it into a batch slot.

The handoff is expressed as a two-stage :class:`~repro.core.dag.WorkflowDAG`
(``prefill --cache--> decode``) compiled onto the event-driven
:class:`~repro.core.workflow.WorkflowEngine` via
``dag.compile(target="engine", handlers=...)``:
each handoff is a workflow invocation, so it *queues and autoscales* exactly
like any workflow function — the decode deployment's concurrency slots are
the engine's in-flight accounting, a handoff that finds every batch slot
busy waits on a free-slot event instead of crashing, and the decode slot is
held (a generator handler parked on a simulator Event) until the pod really
finishes the generation.  Placement still happens before any bulk data
moves; the pull itself goes through the server's own
:class:`~repro.core.transfer.TransferEngine`, so ``handoff_report()`` is
byte-identical to the pre-engine implementation.

Backends:

``xdt``     zero-copy put, direct pull (on hardware: one ICI/DCN traversal,
            prefill-sharding -> decode-sharding).
``staged``  the through-storage baseline: the cache is staged device ->
            host object store -> device (two extra copies + service fees),
            i.e. what S3/ElastiCache-based serving does today.

Both produce bit-identical generations (asserted in tests); they differ in
modeled latency/cost, reported via ``handoff_report()``.
"""
from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from ..core.buffers import BufferRegistry
from ..core.clock import ensure_clock
from ..core.cluster import Event
from ..core.dag import Edge, Stage, WorkflowDAG
from ..core.refs import XDTRef
from ..core.scheduler import ScalingPolicy
from ..core.transfer import TransferEngine, modeled_transfer_seconds
from ..core.workflow import WorkflowEngine
from ..models.config import ModelConfig
from .engine import Request, ServingEngine

PyTree = Any

#: nominal per-handoff cache size declared on the DAG edge (documentation /
#: routing metadata; the real cache's bytes are whatever prefill produced)
NOMINAL_CACHE_BYTES = 32 << 20


def disagg_dag(n_decode_pods: int, cache_bytes: int = NOMINAL_CACHE_BYTES) -> WorkflowDAG:
    """The prefill->decode handoff as a declarative two-stage workflow."""
    return WorkflowDAG(
        "disagg",
        stages=[
            Stage("prefill"),
            Stage("decode", fan=n_decode_pods),
        ],
        edges=[
            Edge("prefill", "decode", cache_bytes, label="cache",
                 handoff="sync", route="xdt"),
        ],
    )


class DisaggregatedServer:
    """One prefill pod + N decode pods over the XDT substrate."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        mesh=None,
        n_decode_pods: int = 2,
        max_batch: int = 4,
        max_len: int = 64,
        backend: str = "xdt",
        clock=None,
    ):
        self.cfg = cfg
        self.backend = backend
        self.clock = ensure_clock(clock)  # virtual under a simulator harness
        engine_backend = "xdt" if backend == "xdt" else "elasticache"
        self.transfer = TransferEngine(
            engine_backend,
            producer_coords=(0,),
            registry=BufferRegistry(max_slots=64, clock=self.clock),
            clock=self.clock,
        )
        # prefill pod: only needs the prefill fn — reuse an engine shell
        self.prefill_pod = ServingEngine(cfg, params, mesh, max_batch=1, max_len=max_len)
        self.decode_pods: List[ServingEngine] = [
            ServingEngine(cfg, params, mesh, max_batch=max_batch, max_len=max_len)
            for _ in range(n_decode_pods)
        ]
        self.pod_of_request: Dict[int, int] = {}
        self.instance_of_request: Dict[int, int] = {}
        self.handoffs = 0
        # -- the handoff workflow: a DAG bound onto the event-driven engine.
        # Custom handlers move the REAL cache through self.transfer; the
        # engine contributes steering, queueing, autoscaling accounting, and
        # virtual-time records.  The decode deployment's fleet is exactly
        # the decode pods (min=max), each with max_batch concurrency slots.
        self.engine = WorkflowEngine(backend="xdt")
        self.dag = disagg_dag(n_decode_pods)
        self._completion: Dict[int, Event] = {}
        self._slot_free: Dict[int, Event] = {}

        def policy(stage: Stage) -> ScalingPolicy:
            if stage.name == "decode":
                return ScalingPolicy(
                    min_instances=n_decode_pods, max_instances=n_decode_pods,
                    target_concurrency=max_batch,
                )
            # the single real prefill pod; slots sized so concurrent
            # handoffs never queue on the producer side
            return ScalingPolicy(
                min_instances=1, max_instances=1,
                target_concurrency=n_decode_pods * max_batch + 1,
            )

        self.binding = self.dag.compile(
            target="engine",
            engine=self.engine,
            policy=policy,
            handlers={"prefill": self._prefill_handler,
                      "decode": self._decode_handler},
        )
        self.control = self.engine.control   # the activator/autoscaler pair
        # decode instance -> pod, assigned on first steer (id-independent:
        # survives an instance being recycled and respawned under a new id)
        self._pod_of_instance: Dict[int, int] = {}

    # ------------------------------------------------------------- handlers
    def _prefill_handler(self, ctx, req: Request):
        """Producer stage: compute the cache, mint the ref, invoke decode."""
        # 1. producer computes the ephemeral object
        cache, first_token = self.prefill_pod.prefill_request(req)
        # 2. producer buffers it and mints the reference (data stays put)
        ref: XDTRef = self.transfer.put(cache, n_retrievals=1)
        # 3/4. control plane picks the consumer, which pulls and decodes
        result = yield ctx.call("disagg.decode", (req, ref, first_token))
        return result

    def _pod_for(self, instance_id: int) -> int:
        """Pod backing a decode instance: first-seen assignment to a free
        pod, evicting mappings of instances the deployment no longer has
        (so a recycled instance's pod becomes assignable again)."""
        pods = self._pod_of_instance
        pod_idx = pods.get(instance_id)
        if pod_idx is None:
            live = self.control.deployments["disagg.decode"].instances
            for dead in [iid for iid in pods if iid not in live]:
                del pods[dead]
            used = set(pods.values())
            pod_idx = next(
                k for k in range(len(self.decode_pods)) if k not in used
            )
            pods[instance_id] = pod_idx
        return pod_idx

    def _decode_handler(self, ctx, payload):
        """Consumer stage: pull the cache into a batch slot; hold the
        concurrency slot until the pod really finishes the generation."""
        req, ref, first_token = payload
        # placement happened at steer time — before the bulk pull below
        pod_idx = self._pod_for(ctx.instance.instance_id)
        pod = self.decode_pods[pod_idx]
        pulled = self.transfer.get(ref)
        while True:
            try:
                slot = pod.slots.index(None)
                break
            except ValueError:
                # every batch slot busy: the handoff queues on this pod
                # until step() frees one (instead of crashing, as the
                # pre-engine implementation did)
                yield self._slot_free_event(pod_idx)
        pod.admit(req, pulled, first_token, slot)
        self.pod_of_request[req.request_id] = pod_idx
        self.instance_of_request[req.request_id] = ctx.instance.instance_id
        self.handoffs += 1
        # park until the real decode completes — the engine releases the
        # concurrency slot only then, which is what the autoscaler measures
        yield self._completion_event(req.request_id)
        return req.request_id

    def _completion_event(self, request_id: int) -> Event:
        ev = self._completion.get(request_id)
        if ev is None:
            ev = self._completion[request_id] = Event(self.engine.sim)
        return ev

    def _slot_free_event(self, pod_idx: int) -> Event:
        ev = self._slot_free.get(pod_idx)
        if ev is None or ev.fired:
            ev = self._slot_free[pod_idx] = Event(self.engine.sim)
        return ev

    # ----------------------------------------------------------------- serve
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        """Prefill-side entry: one handoff workflow request.

        Drives the engine until the handoff either admitted into a decode
        slot or parked behind a full batch; the decode invocation stays
        in flight until the generation completes.
        """
        req = Request(next(self.prefill_pod._ids), np.asarray(prompt, np.int32),
                      max_new_tokens)
        self.engine.submit(self.binding.entry, req)
        self.engine.sim.run()
        return req.request_id

    def step(self) -> None:
        for pod in self.decode_pods:
            if any(s is not None for s in pod.slots):
                pod.step()
        fired = False
        for pod_idx, pod in enumerate(self.decode_pods):
            freed = False
            for rid in list(pod.completed):
                ev = self._completion.pop(rid, None)
                if ev is not None and not ev.fired:
                    ev.set()
                    fired = freed = True
            if freed:
                slot_ev = self._slot_free.pop(pod_idx, None)
                if slot_ev is not None:
                    slot_ev.set()
        if fired:
            # completed handoffs release their decode slots; queued ones
            # admit into the slots just freed
            self.engine.sim.run()

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Request]:
        done: Dict[int, Request] = {}
        steps = 0
        while steps < max_steps:
            if all(all(s is None for s in pod.slots) for pod in self.decode_pods):
                break
            self.step()
            steps += 1
        for pod in self.decode_pods:
            done.update(pod.completed)
        return done

    # ------------------------------------------------------------------ report
    def handoff_report(self) -> Dict[str, float]:
        """Modeled per-handoff latency + engine stats for this backend."""
        stats = self.transfer.stats
        nbytes = stats.bytes_moved / max(1, stats.transfers)
        return {
            "handoffs": float(self.handoffs),
            "avg_cache_bytes": nbytes,
            "modeled_latency_s_per_handoff": (
                stats.modeled_seconds / max(1, stats.transfers)
            ),
            "modeled_latency_s_if_s3": modeled_transfer_seconds("s3", int(nbytes)),
            "modeled_latency_s_if_elasticache": modeled_transfer_seconds(
                "elasticache", int(nbytes)
            ),
            "modeled_latency_s_if_xdt": modeled_transfer_seconds("xdt", int(nbytes)),
        }
