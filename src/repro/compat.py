"""Version-compatibility shims for the pinned container toolchain.

``shard_map`` graduated from ``jax.experimental`` to the top-level namespace
in newer JAX releases; the container pins an older version.  Import it from
here so call sites work on both.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.6: experimental module, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, **kwargs):  # type: ignore[no-redef]
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _experimental_shard_map(f, **kwargs)

__all__ = ["shard_map"]
