from .trainer import Trainer, TrainerConfig, make_train_step
