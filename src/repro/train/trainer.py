"""Training loop: step function factory + fault-tolerant driver.

Scale features (DESIGN.md §7):

* **Microbatched gradient accumulation** — ``grad_accum > 1`` scans over
  microbatches; on TPU the DP gradient reduce-scatter of microbatch *i*
  overlaps the compute of *i+1* under XLA's latency-hiding scheduler (the
  scan structure is what makes the overlap legal).
* **Checkpoint/restart** — atomic async checkpoints every
  ``checkpoint_every`` steps; ``Trainer.run`` resumes from the latest
  committed step, and the deterministic loader regenerates exactly the
  batches after it.  A mid-run crash (tested with injected faults) loses at
  most ``checkpoint_every`` steps and re-trains to bit-identical parameters.
* **Straggler accounting** — per-step deadline; steps that blow through it
  are counted and surfaced (on a real fleet this feeds the scheduler;
  pull-based data feeding already prevents one slow host from stalling the
  collective).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointStore, latest_step
from ..models import make_loss_fn, param_shapes
from ..models.config import ModelConfig
from ..optim import OptConfig, adamw_init, adamw_update, warmup_cosine

PyTree = Any


def make_train_step(
    cfg: ModelConfig,
    mesh,
    opt_cfg: OptConfig,
    remat: str = "full",
    grad_accum: int = 1,
    donate: bool = True,
):
    """Build the jitted fused step: loss + grad (+accumulation) + AdamW."""
    loss_fn = make_loss_fn(cfg, mesh, remat)
    schedule = warmup_cosine(opt_cfg.peak_lr, opt_cfg.warmup_steps, opt_cfg.total_steps)

    zero1 = opt_cfg.zero1 and mesh is not None
    if zero1:
        from ..distributed.sharding import rules_for

        rules = rules_for(cfg, mesh)
        axes_tree = jax.tree.map(
            lambda spec: tuple(spec[1]), param_shapes(cfg),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple) and all(isinstance(i, int) for i in x[0]),
        )

        def _z1(tree):
            return jax.tree.map(
                lambda ax, v: jax.lax.with_sharding_constraint(
                    v, rules.zero1_named(list(ax), v.shape)
                ),
                axes_tree, tree,
                is_leaf=lambda x: isinstance(x, tuple),  # axes tuples are leaves
            )

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (
                    acc[0] + l / grad_accum,
                    jax.tree.map(lambda a, b: a + b.astype(a.dtype) / grad_accum, acc[1], g),
                ), None

            zero = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            (loss, grads), _ = jax.lax.scan(body, zero, micro)
        if zero1:
            # shard grads over the DP axes BEFORE the f32 update: GSPMD
            # lowers the DP all-reduce to reduce-scatter, and the sharded
            # moments/update below all-gather only the bf16 params back.
            grads = _z1(grads)
        new_params, new_state, gnorm = adamw_update(params, grads, opt_state, opt_cfg, schedule)
        if zero1:
            new_state = dict(new_state, mu=_z1(new_state["mu"]), nu=_z1(new_state["nu"]))
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": gnorm,
            "lr": schedule(new_state["step"]),
        }
        return new_params, new_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 25
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_deadline_s: Optional[float] = None
    grad_accum: int = 1
    remat: str = "full"


class SimulatedFailure(RuntimeError):
    """Raised by fault-injection hooks to model a node loss mid-run."""


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        mesh,
        opt_cfg: OptConfig,
        tcfg: TrainerConfig,
        workdir: str,
        batch_at: Callable[[int], Dict[str, np.ndarray]],
        fault_hook: Optional[Callable[[int], None]] = None,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg
        self.batch_at = batch_at
        self.fault_hook = fault_hook
        self.store = CheckpointStore(workdir, keep=tcfg.keep_checkpoints)
        self.step_fn = make_train_step(
            cfg, mesh, opt_cfg, remat=tcfg.remat, grad_accum=tcfg.grad_accum
        )
        self.params = params
        self.opt_state = adamw_init(params)
        self.start_step = 0
        self.metrics_log: list = []
        self.straggler_steps = 0
        self._logical_axes = {
            "params": jax.tree.map(
                lambda spec: tuple(spec[1]), param_shapes(cfg),
                is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                and isinstance(x[0], tuple) and all(isinstance(i, int) for i in x[0]),
            )
        }

    # -- persistence -------------------------------------------------------------
    def _save(self, step: int) -> None:
        tree = {"params": self.params, "opt": self.opt_state}
        self.store.save_async(step, tree)

    def try_resume(self) -> bool:
        last = latest_step(self.store.directory)
        if last is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        restored = self.store.restore(last, like, mesh=self.mesh)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.start_step = last
        return True

    # -- main loop -----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        try:
            return self._run_loop()
        finally:
            # Flush outstanding async checkpoint IO even when the loop raises:
            # the snapshot was taken before the fault, so the committed
            # checkpoint must land on disk for restart to see it.
            try:
                self.store.wait()
            except Exception:
                pass  # surfaced by the next save/wait; don't mask the fault

    def _run_loop(self) -> Dict[str, Any]:
        self.try_resume()
        step = self.start_step
        while step < self.tcfg.steps:
            t0 = time.perf_counter()
            if self.fault_hook is not None:
                self.fault_hook(step)  # may raise SimulatedFailure
            batch = self.batch_at(step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            step += 1
            dt = time.perf_counter() - t0
            if (
                self.tcfg.straggler_deadline_s is not None
                and dt > self.tcfg.straggler_deadline_s
            ):
                self.straggler_steps += 1
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                self.metrics_log.append(
                    {"step": step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "lr": float(metrics["lr"]), "sec": dt}
                )
            if step % self.tcfg.checkpoint_every == 0 or step == self.tcfg.steps:
                self._save(step)
        self.store.wait()
        return {
            "final_step": step,
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "stragglers": self.straggler_steps,
            "log": self.metrics_log,
        }
